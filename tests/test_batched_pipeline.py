"""Batched multi-namenode request pipeline (paper §2.2, §7.2).

The two contract properties from the issue:
  1. batched execution leaves the store in EXACTLY the state sequential
     execution does (strict full-table equality on a single namenode;
     logical-namespace equality across namenode counts, where physical
     ids legitimately differ);
  2. OpCost accounting is conserved across batching: the merge of per-
     namenode aggregates == the pipeline's total == the merge of every
     successful op's cost.
Plus: the vectorized phash partition grouping agrees with the store's
partitioner, batching actually saves round trips, the batched DES scales
with namenode count, and the trace generator matches the §7.2 mix.
"""

from repro.core import (BatchPlanner, MetadataStore, NamenodeCluster,
                        OpCost, PlannedRequestPipeline, RequestPipeline,
                        WorkloadOp, format_fs, materialize_namespace,
                        namespace_snapshot)
from repro.core.cluster_sim import BatchedHopsFSSim, profile_ops
from repro.core.store import _hash_key
from repro.core.tables import ROOT_ID, make_inode
from repro.core.workload import (NamespaceSpec, SPOTIFY_TRACE_MIX,
                                 SpotifyWorkload, SyntheticNamespace,
                                 TraceReplay, make_spotify_trace)


def _build(n_namenodes: int, *, n_dirs: int = 16, files_per_dir: int = 4):
    store = MetadataStore(n_datanodes=4)
    format_fs(store)
    cluster = NamenodeCluster(store, n_namenodes)
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=n_dirs,
                            files_per_dir=files_per_dir)
    materialize_namespace(cluster.namenodes[0], ns)
    return store, cluster, ns


def _trace(ns, n_ops=300, seed=5):
    return make_spotify_trace(ns, n_ops, seed=seed)


# ---------------------------------------------------------------------------
# 1. state equivalence
# ---------------------------------------------------------------------------

def test_batched_equals_sequential_state_single_nn():
    """Strict equality: with one namenode, batched execution must leave
    every table byte-identical to sequential execution (same mtimes, same
    ids — nothing may be reordered observably)."""
    ns_ref = SyntheticNamespace(NamespaceSpec(), n_dirs=16, files_per_dir=4)
    trace = _trace(ns_ref)
    store_seq, cluster_seq, _ = _build(1)
    seq = RequestPipeline(cluster_seq, batch_size=1).run(trace)
    store_bat, cluster_bat, _ = _build(1)
    bat = RequestPipeline(cluster_bat, batch_size=8).run(trace)
    assert store_seq.dump_state() == store_bat.dump_state()
    # same per-op outcome stream too
    assert [(o.ok, o.error) for o in seq.outcomes] == \
           [(o.ok, o.error) for o in bat.outcomes]
    assert bat.batched_fraction > 0.2     # batching actually engaged


def test_batched_equals_sequential_namespace_multi_nn():
    """Across namenode counts the physical ids differ (per-NN id-allocator
    blocks) but the logical namespace must be identical."""
    ns_ref = SyntheticNamespace(NamespaceSpec(), n_dirs=16, files_per_dir=4)
    trace = _trace(ns_ref)
    store_seq, cluster_seq, _ = _build(1)
    RequestPipeline(cluster_seq, batch_size=1).run(trace)
    store_bat, cluster_bat, _ = _build(4)
    RequestPipeline(cluster_bat, batch_size=8).run(trace)
    assert namespace_snapshot(store_seq) == namespace_snapshot(store_bat)


# ---------------------------------------------------------------------------
# 2. cost conservation
# ---------------------------------------------------------------------------

def test_opcost_conserved_across_batching():
    _, cluster, ns = _build(4)
    stats = RequestPipeline(cluster, batch_size=8).run(_trace(ns))
    per_nn = OpCost()
    for c in stats.per_nn_cost.values():
        per_nn.merge(c)
    per_op = OpCost()
    for o in stats.outcomes:
        if o.ok:
            per_op.merge(o.result.cost)
    assert per_nn.as_dict() == stats.total_cost.as_dict() == per_op.as_dict()
    # every op got an outcome, and namenode op counters agree
    assert stats.ok + stats.failed == len(stats.outcomes)
    assert sum(stats.per_nn_ops.values()) == stats.ok


def test_batching_saves_round_trips():
    ns_ref = SyntheticNamespace(NamespaceSpec(), n_dirs=16, files_per_dir=4)
    trace = _trace(ns_ref)
    _, cluster_seq, _ = _build(1)
    seq = RequestPipeline(cluster_seq, batch_size=1).run(trace)
    _, cluster_bat, _ = _build(1)
    bat = RequestPipeline(cluster_bat, batch_size=16).run(trace)
    assert bat.total_cost.round_trips < seq.total_cost.round_trips
    # reads dominate the §7.2 mix => savings should be substantial
    assert bat.total_cost.round_trips <= 0.95 * seq.total_cost.round_trips


def test_concurrent_pipeline_row_lock_contention():
    """Threaded namenodes hammering the SAME rows: a mixed read/write
    trace where every mutation targets one of a handful of files (target
    row X locks), one shared directory (parent mtime + quota row), and
    one lease holder. No op may be lost, and OpCost accounting must stay
    conserved under real row-lock contention."""
    store, cluster, _ = _build(4, n_dirs=4, files_per_dir=4)
    nn0 = cluster.namenodes[0]
    hot_dir = "/w/hot"
    nn0.ops.mkdirs(hot_dir)
    hot = [f"{hot_dir}/h{i}" for i in range(6)]
    for p in hot:
        nn0.ops.create(p)
    wops = []
    for i in range(240):
        k = i % 6
        if i % 4 == 0:
            wops.append(WorkloadOp("chmod_file", hot[k],
                                   args={"perm": 0o600 + (i % 8)}))
        elif i % 4 == 1:
            wops.append(WorkloadOp("read", hot[k]))
        elif i % 4 == 2:
            wops.append(WorkloadOp("set_replication", hot[k],
                                   args={"repl": 1 + (i % 3)}))
        else:
            wops.append(WorkloadOp("create", f"{hot_dir}/new{i:04d}"))
    stats = RequestPipeline(cluster, batch_size=8,
                            concurrent=True).run(wops)
    # nothing lost: every op got exactly one outcome
    assert stats.ok + stats.failed == len(wops)
    assert all(o is not None for o in stats.outcomes)
    # the overwhelming majority must succeed (row-lock waits block, they
    # don't fail; only a >1.2s stall would surface as LockTimeout)
    assert stats.ok >= 0.95 * len(wops)
    # conserved accounting under contention
    per_nn = OpCost()
    for c in stats.per_nn_cost.values():
        per_nn.merge(c)
    per_op = OpCost()
    for o in stats.outcomes:
        if o.ok:
            per_op.merge(o.result.cost)
    assert per_nn.as_dict() == stats.total_cost.as_dict() \
        == per_op.as_dict()
    assert sum(stats.per_nn_ops.values()) == stats.ok
    # every create landed exactly once
    snap = namespace_snapshot(store)
    assert all(f"{hot_dir}/new{i:04d}" in snap
               for i in range(3, 240, 4))


def test_concurrent_pipeline_namespace_consistent():
    """Threaded namenodes over the shared store: every op completes and
    the namespace matches a sequential run of the same trace (the trace's
    mutations target distinct paths, so interleaving is benign)."""
    ns_ref = SyntheticNamespace(NamespaceSpec(), n_dirs=16, files_per_dir=4)
    trace = _trace(ns_ref, n_ops=200)
    store_seq, cluster_seq, _ = _build(1)
    RequestPipeline(cluster_seq, batch_size=1).run(trace)
    store_con, cluster_con, _ = _build(4)
    stats = RequestPipeline(cluster_con, batch_size=8,
                            concurrent=True).run(trace)
    assert stats.ok + stats.failed == len(trace)
    assert namespace_snapshot(store_con) == namespace_snapshot(store_seq)


# ---------------------------------------------------------------------------
# 2b. grouped WRITE path (create/mkdirs/setattr sharing one transaction)
# ---------------------------------------------------------------------------

def _single_nn():
    store = MetadataStore(n_datanodes=4)
    format_fs(store)
    cluster = NamenodeCluster(store, 1)
    nn = cluster.namenodes[0]
    nn.ops.mkdirs("/a/b")
    nn.ops.mkdirs("/a/c")
    return store, nn


def test_grouped_writes_equal_sequential_state():
    """Runs of creates/mkdirs/setattrs share one transaction; ids, mtimes
    and every row must still be byte-identical to sequential execution
    (execute phases run in submission order inside the group)."""
    wops = ([WorkloadOp("create", f"/a/b/f{i}") for i in range(6)]
            + [WorkloadOp("create", "/a/c/g0"),
               WorkloadOp("create", "/a/b/f0")]          # in-group dup
            + [WorkloadOp("mkdirs", f"/a/c/d{i}") for i in range(4)]
            + [WorkloadOp("chmod_file", f"/a/b/f{i}",
                          args={"perm": 0o600}) for i in range(4)])
    store_b, nn_b = _single_nn()
    out_b = nn_b.execute_batch(wops)
    store_s, nn_s = _single_nn()
    out_s = [nn_s._safe_exec(w) for w in wops]
    assert store_b.dump_state() == store_s.dump_state()
    assert [(o.ok, o.error) for o in out_b] == \
           [(o.ok, o.error) for o in out_s]
    # the grouped write path actually engaged, including the dup error
    assert nn_b.batched_write_ops >= 10
    assert [o.error for o in out_b].count("FileAlreadyExists") == 1
    # conserved accounting
    agg = OpCost()
    for o in out_b:
        if o.ok:
            agg.merge(o.result.cost)
    assert agg.as_dict() == nn_b.agg_cost.as_dict()


def test_grouped_writes_save_round_trips():
    """A run of creates through the grouped path costs fewer round trips
    than the same creates executed sequentially."""
    wops = [WorkloadOp("create", f"/a/b/n{i}") for i in range(8)]
    store_b, nn_b = _single_nn()
    for o in nn_b.execute_batch(wops):
        assert o.ok and o.batched
    store_s, nn_s = _single_nn()
    for w in wops:
        assert nn_s._safe_exec(w).ok
    # agg_cost only counts pipeline-served ops (the _single_nn warmup goes
    # through HopsFSOps directly), so this compares exactly the two runs
    assert nn_b.agg_cost.round_trips < nn_s.agg_cost.round_trips


# ---------------------------------------------------------------------------
# 2c. planned mode: client-side columnar batch planner
# ---------------------------------------------------------------------------

def test_planned_pipeline_equivalence_and_savings():
    """The ISSUE acceptance bar, on the quick-mode Spotify trace at 4
    namenodes: planner mode cuts total DB round trips >= 20% vs the
    reactive pipeline, the batched fraction (reads+writes) strictly
    exceeds the read-only batched fraction, the local round-trip share
    rises, and planned/reactive/sequential execution all converge to the
    same logical namespace."""
    ns_ref = SyntheticNamespace(NamespaceSpec(), n_dirs=20, files_per_dir=4)
    trace = make_spotify_trace(ns_ref, 600, seed=5)

    def build():
        store = MetadataStore(n_datanodes=4)
        format_fs(store)
        cluster = NamenodeCluster(store, 4)
        ns = SyntheticNamespace(NamespaceSpec(), n_dirs=20,
                                files_per_dir=4)
        materialize_namespace(cluster.namenodes[0], ns)
        return store, cluster

    store_seq, cl = build()
    seq = RequestPipeline(cl, batch_size=1).run(trace)
    store_rea, cl = build()
    rea = RequestPipeline(cl, batch_size=16).run(trace)
    store_pln, cl = build()
    pipe = PlannedRequestPipeline(cl, batch_size=16)
    pln = pipe.run(trace)
    # every op accounted for, nothing spuriously failed by planning
    assert pln.ok + pln.failed == len(trace)
    assert pln.failed <= seq.failed
    # >= 20% fewer DB round trips than the reactive pipeline (measured
    # ~40%; the bar leaves headroom for mix drift)
    assert pln.total_cost.round_trips <= 0.8 * rea.total_cost.round_trips
    # grouped writes engaged: total batched share strictly above read-only
    assert pln.batched_write_fraction > 0
    assert pln.batched_fraction > pln.batched_read_fraction
    assert pln.batched_fraction > rea.batched_fraction
    # DAT alignment: local round-trip share rises under the planner
    assert pln.local_rt_fraction > rea.local_rt_fraction
    assert pln.local_rt_fraction > seq.local_rt_fraction
    # final-state equivalence across all three execution modes
    snap = namespace_snapshot(store_seq)
    assert snap == namespace_snapshot(store_rea)
    assert snap == namespace_snapshot(store_pln)
    # planner telemetry: client-side resolutions + fused kernel ran
    rep = pipe.plan_report
    assert rep is not None and rep.planned_ops > 0
    assert rep.batches > 0 and rep.windows > 0


def test_planned_pipeline_cost_conserved():
    ns_ref = SyntheticNamespace(NamespaceSpec(), n_dirs=16, files_per_dir=4)
    trace = make_spotify_trace(ns_ref, 300, seed=7)
    store, cluster, ns = _build(4)
    stats = PlannedRequestPipeline(cluster, batch_size=8).run(trace)
    per_nn = OpCost()
    for c in stats.per_nn_cost.values():
        per_nn.merge(c)
    per_op = OpCost()
    for o in stats.outcomes:
        if o.ok:
            per_op.merge(o.result.cost)
    assert per_nn.as_dict() == stats.total_cost.as_dict() \
        == per_op.as_dict()
    assert stats.ok + stats.failed == len(stats.outcomes)
    del store, ns


def test_planned_concurrent_namespace_consistent():
    ns_ref = SyntheticNamespace(NamespaceSpec(), n_dirs=16, files_per_dir=4)
    trace = make_spotify_trace(ns_ref, 200, seed=5)
    store_seq, cluster_seq, _ = _build(1)
    RequestPipeline(cluster_seq, batch_size=1).run(trace)
    store_con, cluster_con, _ = _build(4)
    stats = PlannedRequestPipeline(cluster_con, batch_size=8,
                                   concurrent=True).run(trace)
    assert stats.ok + stats.failed == len(trace)
    assert namespace_snapshot(store_con) == namespace_snapshot(store_seq)


def test_planner_orders_unresolved_ops():
    """A read of a path created earlier in the same window cannot resolve
    client-side, so it is pinned to submission order — it must never be
    dealt ahead of the create and spuriously fail."""
    _store, cluster, _ = _build(2)
    trace = []
    for i in range(30):
        p = f"/w/newfile{i:02d}"
        trace.append(WorkloadOp("create", p))
        trace.append(WorkloadOp("read", p))
    stats = PlannedRequestPipeline(cluster, batch_size=8).run(trace)
    assert stats.failed == 0
    assert stats.ok == len(trace)


def test_planner_pins_conflicting_mutations():
    """Destructive ops, duplicate mutation paths, and prefix-related
    mutations are pinned (kept in submission order); independent creates
    and all reads stay free for partition-aligned dealing."""
    _store, cluster, _ = _build(2)
    planner = BatchPlanner(cluster, batch_size=4)
    wops = [
        WorkloadOp("read", "/w/f0000.parquet"),            # 0 free
        WorkloadOp("create", "/w/x1"),                     # 1 free
        WorkloadOp("create", "/w/x2"),                     # 2 free
        WorkloadOp("delete_file", "/w/f0001.parquet"),     # 3 destructive
        WorkloadOp("mkdirs", "/w/sub/leaf"),               # 4 prefix of 5
        WorkloadOp("chmod_file", "/w/sub",
                   args={"perm": 0o700}),                  # 5 prefix of 4
        WorkloadOp("create", "/w/dup"),                    # 6 dup with 7
        WorkloadOp("create", "/w/dup"),                    # 7 dup with 6
    ]
    batches = planner.plan(wops)
    pinned = {i for b in batches if b.ordered for i in b.indices}
    assert pinned == {3, 4, 5, 6, 7}
    # pinned batches preserve submission order
    ordered = [i for b in batches if b.ordered for i in b.indices]
    assert ordered == sorted(ordered)
    # every op dealt exactly once
    dealt = sorted(i for b in batches for i in b.indices)
    assert dealt == list(range(len(wops)))


# ---------------------------------------------------------------------------
# 3. vectorized partition grouping (phash kernel path)
# ---------------------------------------------------------------------------

def test_vectorized_partitions_match_store():
    from repro.core.namenode import _partitions_for
    store = MetadataStore(n_datanodes=4)
    ids = [1, 2, 3, 999, 12345, 2**31 - 1, 64, 65]
    expect = [store.table("inode").partition_of(i) for i in ids]
    # scalar path (small batch) and forced kernel path must both agree
    assert _partitions_for(ids, store.n_partitions) == expect
    assert _partitions_for(ids, store.n_partitions, min_batch=1) == expect
    assert expect == [_hash_key(i) % store.n_partitions for i in ids]


def test_phash_fallback_recovers_after_transient_failure(monkeypatch):
    """A transient kernel failure must not latch the scalar fallback
    forever: the probe re-enables the vectorized path after a bounded
    number of calls (the old module-global bool stayed False for the
    process lifetime)."""
    import repro.kernels.phash.ops as phash_ops
    from repro.core import namenode as nn_mod
    probe = nn_mod._KernelProbe(reprobe_every=3)
    monkeypatch.setattr(nn_mod, "_phash_probe", probe)
    calls = {"kernel": 0, "fail_next": 1}
    real = phash_ops.phash_partitions

    def flaky(ids, n_partitions, **kw):
        calls["kernel"] += 1
        if calls["fail_next"] > 0:
            calls["fail_next"] -= 1
            raise RuntimeError("transient accelerator failure")
        return real(ids, n_partitions, **kw)

    monkeypatch.setattr(phash_ops, "phash_partitions", flaky)
    store = MetadataStore(n_datanodes=4)
    ids = list(range(40))
    expect = [_hash_key(i) % store.n_partitions for i in ids]
    # 1st call: kernel raises, scalar fallback still returns right answer
    assert nn_mod._partitions_for(ids, store.n_partitions,
                                  min_batch=1) == expect
    assert probe.failures == 1
    # next calls fall back WITHOUT touching the kernel (bounded backoff)
    for _ in range(2):
        assert nn_mod._partitions_for(ids, store.n_partitions,
                                      min_batch=1) == expect
    assert calls["kernel"] == 1
    # ...then the re-probe fires, the kernel works again, and the
    # vectorized path stays enabled
    assert nn_mod._partitions_for(ids, store.n_partitions,
                                  min_batch=1) == expect
    assert calls["kernel"] == 2 and probe.failures == 0
    assert nn_mod._partitions_for(ids, store.n_partitions,
                                  min_batch=1) == expect
    assert calls["kernel"] == 3


def test_namespace_snapshot_deep_namespace():
    """path_of is iterative: a namespace deeper than Python's recursion
    limit (~1000) must still snapshot completely."""
    depth = 2200
    store = MetadataStore(n_datanodes=4)
    format_fs(store)
    t = store.table("inode")
    parent = ROOT_ID
    for i in range(depth):
        iid = 10 + i
        t.put(make_inode(iid, parent, f"d{i}", True))
        parent = iid
    snap = namespace_snapshot(store)
    assert len(snap) == depth
    deepest = "/" + "/".join(f"d{i}" for i in range(depth))
    assert deepest in snap


# ---------------------------------------------------------------------------
# 4. trace generation + DES scaling
# ---------------------------------------------------------------------------

def test_spotify_trace_mix():
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=30)
    wl = SpotifyWorkload(ns, seed=3, mix=SPOTIFY_TRACE_MIX)
    hist = wl.mix_histogram(20_000)
    assert 64.0 < hist.get("read", 0) < 70.0          # ~67% getBlockLocations
    assert 10.0 < hist.get("ls", 0) < 14.0            # ~12% listStatus


def test_trace_replay_deterministic():
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=10)
    trace = make_spotify_trace(ns, 50, seed=9)
    r1, r2 = TraceReplay(trace), TraceReplay(trace)
    a = [r1.next_op() for _ in range(120)]
    b = [r2.next_op() for _ in range(120)]
    assert a == b
    assert a[:50] == trace and a[50:100] == trace      # cyclic


def test_batched_sim_throughput_scales_with_namenodes():
    profiles = profile_ops()
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=30)
    trace = make_spotify_trace(ns, 1000, seed=11)
    tps = []
    for n_nn in (1, 4):
        sim = BatchedHopsFSSim(n_namenodes=n_nn, n_ndb=8,
                               profiles=profiles, batch_size=16, seed=1)
        sim.start_clients(150 * n_nn, TraceReplay(trace))
        tps.append(sim.run(0.15).throughput)
    assert tps[1] > 2.0 * tps[0]


def test_batched_sim_planned_mode_batches_more():
    """The DES mirror of the planner: partition-aligned, type-pure batch
    pulls collapse far more validation exchanges than FIFO slices."""
    profiles = profile_ops()
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=30)
    trace = make_spotify_trace(ns, 1000, seed=11)
    stats = {}
    for planned in (False, True):
        sim = BatchedHopsFSSim(n_namenodes=4, n_ndb=8, profiles=profiles,
                               batch_size=16, seed=1, planned=planned)
        sim.start_clients(600, TraceReplay(trace))
        res = sim.run(0.15)
        stats[planned] = (res.completed, sim.batched_ops, res.throughput)
    assert stats[True][0] > 0
    # planned pulls batch a much larger share of the completed ops
    assert stats[True][1] / stats[True][0] > \
        1.5 * stats[False][1] / stats[False][0]
    # and throughput does not regress
    assert stats[True][2] >= 0.95 * stats[False][2]


def test_batched_sim_batching_engages_under_load():
    profiles = profile_ops()
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=30)
    trace = make_spotify_trace(ns, 1000, seed=11)
    sim = BatchedHopsFSSim(n_namenodes=1, n_ndb=4, profiles=profiles,
                           batch_size=16, seed=1)
    sim.start_clients(400, TraceReplay(trace))
    res = sim.run(0.15)
    assert res.completed > 0
    assert sim.batched_ops > 0.2 * res.completed
    # nn-side counter ticks at batch finish; client-side `completed` half an
    # RTT later, so in-flight ops at the horizon leave nn counters ahead
    assert sum(sim.nn_ops_completed) >= res.completed
