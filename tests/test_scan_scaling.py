"""Scan-scaling guard for the transaction read-your-writes overlay.

A grouped write transaction that interleaves indexed scans with writes —
the shape of G add_blocks over G distinct files, each ``_file_scan``
probing ``block`` by ``inode_id`` — used to go QUADRATIC in G: every
``ppis``/``index_scan`` walked the transaction's entire dirty set just to
discard the rows whose indexed value didn't match.  The
``Transaction._dirty_idx`` candidate index scopes the overlay walk to the
pending rows that CAN match; ``Transaction.overlay_scanned`` counts the
candidates actually examined, and this guard asserts 10x the dirty rows
costs ~10x the overlay work — not ~100x.
"""
from repro.core import MetadataStore, format_fs
from repro.core.tables import make_block
from repro.core.transactions import Transaction


def _interleaved_workload(n):
    """One txn: for each of n distinct inodes, insert a block row then
    ppis-scan the block table for that inode (read-your-writes shape).
    Returns the transaction with its overlay counter populated."""
    store = MetadataStore(n_datanodes=4)
    format_fs(store)
    txn = Transaction(store, partition_hint=("block", 1))
    for i in range(n):
        inode_id = 1000 + i
        txn.write("block", make_block(5000 + i, inode_id, 0))
        rows = txn.ppis("block", "inode_id", inode_id)
        assert [r["block_id"] for r in rows] == [5000 + i]
    scanned = txn.overlay_scanned
    txn.abort()
    return scanned


def test_indexed_overlay_scan_work_is_linear():
    n = 40
    small = _interleaved_workload(n)
    big = _interleaved_workload(10 * n)
    # each scan should examine O(1) candidates (exactly the one pending
    # row for that inode), so work is ~N, never ~N^2/2
    assert small <= 3 * n, small
    assert big <= 3 * (10 * n), big
    # the scaling assertion proper: 10x rows => ~10x overlay work. The
    # old full-dirty-set walk gives big/small ≈ 100.
    assert big <= 30 * max(1, small), (small, big)


def test_unindexed_predicate_scan_still_sees_all_dirty_rows():
    """full_scan has no index key — it must keep walking the whole dirty
    set (correctness over speed for arbitrary predicates)."""
    store = MetadataStore(n_datanodes=4)
    format_fs(store)
    txn = Transaction(store, partition_hint=("block", 1))
    for i in range(20):
        txn.write("block", make_block(6000 + i, 2000 + i, 0))
    rows = txn.full_scan("block", lambda r: r["inode_id"] >= 2010)
    assert sorted(r["block_id"] for r in rows) == \
        [6000 + i for i in range(10, 20)]
    assert txn.overlay_scanned >= 20      # predicate path: all dirty rows
    txn.abort()


def test_overlay_index_tracks_rewrites_and_deletes():
    """Read-your-writes correctness through the candidate index: value
    rewrites move a pending row between candidate lists, deletes drop it,
    and a stale candidate can never surface a wrong row."""
    store = MetadataStore(n_datanodes=4)
    format_fs(store)
    txn = Transaction(store, partition_hint=("block", 1))
    txn.write("block", make_block(7000, 3000, 0))
    assert [r["block_id"] for r in txn.ppis("block", "inode_id", 3000)] \
        == [7000]
    # rewrite under a new indexed value: old list must no longer yield it
    txn.write("block", make_block(7000, 3001, 0))
    assert txn.ppis("block", "inode_id", 3000) == []
    assert [r["block_id"] for r in txn.ppis("block", "inode_id", 3001)] \
        == [7000]
    # delete: gone from every candidate list
    txn.delete("block", (7000,))
    assert txn.ppis("block", "inode_id", 3001) == []
    txn.abort()


def test_overlay_merges_with_committed_rows():
    """The indexed overlay adds pending rows ON TOP of committed ones —
    a scan mid-transaction sees both, without duplicates."""
    store = MetadataStore(n_datanodes=4)
    format_fs(store)
    store.table("block").put(make_block(8000, 4000, 0))
    txn = Transaction(store, partition_hint=("block", 1))
    txn.write("block", make_block(8001, 4000, 1))
    rows = txn.ppis("block", "inode_id", 4000)
    assert sorted(r["block_id"] for r in rows) == [8000, 8001]
    # updating the COMMITTED row through the txn must not duplicate it
    txn.write("block", make_block(8000, 4000, 0, size=5))
    rows = txn.ppis("block", "inode_id", 4000)
    assert sorted(r["block_id"] for r in rows) == [8000, 8001]
    assert [r for r in rows if r["block_id"] == 8000][0]["size"] == 5
    txn.abort()
