"""Property-based tests (hypothesis) on system invariants.

Skipped cleanly when `hypothesis` is not installed (it is a dev-only
dependency, see requirements-dev.txt) — the tier-1 suite must not fail
on environments that only have the runtime deps."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
from hypothesis import HealthCheck, given, settings  # noqa: E402

from repro.core import (HopsFSOps, InodeHintCache, MetadataStore, format_fs)
from repro.core.hdfs_baseline import HDFSNamenode
from repro.core.store import _hash_key
from repro.core.workload import NamespaceSpec, SpotifyWorkload, SyntheticNamespace

SLOW = settings(max_examples=25,
                suppress_health_check=[HealthCheck.too_slow], deadline=None)


# ---------------------------------------------------------------------------
# partitioning invariants
# ---------------------------------------------------------------------------

@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.integers(min_value=1, max_value=512))
def test_hash_partition_in_range(key, nparts):
    assert 0 <= _hash_key(key) % nparts < nparts


@given(st.lists(st.integers(min_value=0, max_value=2**31 - 1),
                min_size=64, max_size=256, unique=True))
def test_hash_partition_balance(keys):
    """No partition should swallow everything (mixing works)."""
    parts = [_hash_key(k) % 16 for k in keys]
    counts = np.bincount(parts, minlength=16)
    assert counts.max() <= len(keys) * 0.5


@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_phash_kernel_matches_store_hash(key):
    """The TPU partition hash and the metadata store agree on placement."""
    from repro.kernels.phash.ref import phash_ref
    expect = _hash_key(key) % 64
    got = int(phash_ref(np.asarray([key], np.int64) & 0xFFFFFFFF, 64)[0])
    assert got == expect


# ---------------------------------------------------------------------------
# HopsFS vs in-memory oracle (HDFS baseline) equivalence
# ---------------------------------------------------------------------------

_name = st.text(alphabet="abcdef", min_size=1, max_size=4)


@st.composite
def fs_script(draw):
    ops = []
    known = ["/w"]
    for _ in range(draw(st.integers(min_value=1, max_value=12))):
        kind = draw(st.sampled_from(["mkdir", "create", "stat", "ls"]))
        if kind in ("mkdir", "create"):
            base = draw(st.sampled_from(known))
            path = base + "/" + draw(_name)
            if kind == "mkdir":
                known.append(path)
            ops.append((kind, path))
        else:
            ops.append((kind, draw(st.sampled_from(known))))
    return ops


@SLOW
@given(fs_script())
def test_hopsfs_matches_oracle(script):
    """Any script of namespace ops leaves HopsFS and the single-node
    oracle in identical visible states."""
    store = MetadataStore(n_datanodes=2)
    format_fs(store)
    hops = HopsFSOps(store, 0)
    oracle = HDFSNamenode()
    hops.mkdir("/w")
    oracle.mkdir("/w")
    for kind, path in script:
        r_h = r_o = None
        e_h = e_o = False
        try:
            if kind == "mkdir":
                hops.mkdir(path)
            elif kind == "create":
                hops.create(path)
            elif kind == "stat":
                r_h = hops.stat(path).value["is_dir"]
            else:
                r_h = hops.listing(path).value
        except Exception:
            e_h = True
        try:
            if kind == "mkdir":
                oracle.mkdir(path)
            elif kind == "create":
                oracle.create(path)
            elif kind == "stat":
                r_o = oracle.stat(path)["is_dir"]
            else:
                r_o = oracle.ls(path)
        except Exception:
            e_o = True
        if kind in ("stat", "ls"):
            assert e_h == e_o and r_h == r_o, (kind, path)


# ---------------------------------------------------------------------------
# hint cache invariants
# ---------------------------------------------------------------------------

@given(st.lists(st.tuples(st.integers(0, 50), _name), min_size=1,
                max_size=64))
def test_hint_cache_lru_bound(entries):
    c = InodeHintCache(capacity=16)
    for i, (pid, name) in enumerate(entries):
        c.put(pid, name, i + 2)
    assert len(c._lru) <= 16


@given(st.integers(min_value=2, max_value=30))
def test_cache_hit_cost_depth_invariant(depth):
    """Table 3's structural property, as a property test."""
    store = MetadataStore(n_datanodes=2)
    format_fs(store)
    fs = HopsFSOps(store, 0)
    d = "/" + "/".join(f"l{i}" for i in range(depth - 1))
    fs.mkdirs(d)
    fs.create(d + "/f")
    fs.stat(d + "/f")
    c1 = fs.stat(d + "/f").cost.round_trips
    assert c1 == 3          # PK_r + 2 batches, independent of depth


# ---------------------------------------------------------------------------
# workload generator matches Table 1
# ---------------------------------------------------------------------------

def test_workload_mix_matches_table1():
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=30)
    wl = SpotifyWorkload(ns, seed=3)
    hist = wl.mix_histogram(30_000)
    reads = hist.get("read", 0)
    assert 66.0 < reads < 71.5                      # 68.73% ±
    assert 15.5 < hist.get("stat", 0) < 18.5        # 17%
    assert 7.5 < hist.get("ls", 0) < 10.5           # 9%
    mutating = sum(hist.get(k, 0) for k in
                   ("create", "delete_file", "delete_subtree",
                    "rename_file", "mkdirs", "add_block", "append"))
    assert mutating < 6.0                           # ~95% read-mostly
