"""Pallas (interpret=True) vs numpy-oracle regression for the columnar
engine's two fused kernels — grouped PK validation (``pkval``) and
vectorized hint-chain resolution (``hintchain``) — mirroring the phash
suite's pattern, plus ``_KernelProbe`` fallback-and-recovery coverage for
the per-family availability gates."""
import numpy as np
import pytest

import repro.core.columnar as columnar
from repro.core.columnar import AMBIG, EMPTY, HashIndex, MAX_PROBE
from repro.core.namenode import _KernelProbe, _with_phash_kernel
from repro.core.workload import name_hash32


def _filled_index(n=300, seed=0, offset=0):
    rng = np.random.default_rng(seed)
    idx = HashIndex()
    keys = []
    for i in range(n):
        par = int(rng.integers(1, 50_000)) + offset
        nam = name_hash32(f"e{seed}_{i}")
        idx.set(par, nam, i + 2)
        keys.append((par, nam, i + 2))
    return idx, keys


# ---------------------------------------------------------------------------
# pkval
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_probes", [8, 129, 1000])
def test_pkval_kernel_matches_ref(n_probes):
    from repro.kernels.pkval.ops import pkval_lookup
    from repro.kernels.pkval.ref import pkval_ref
    idx, keys = _filled_index(400, seed=1)
    rng = np.random.default_rng(2)
    probes = []
    for i in range(n_probes):
        if rng.random() < 0.6:
            par, nam, _ = keys[int(rng.integers(len(keys)))]
        else:
            par, nam = int(rng.integers(60_000, 90_000)), \
                name_hash32(f"miss{i}")
        probes.append((par, nam))
    par = np.array([p for p, _ in probes], np.int64)
    nam = np.array([h for _, h in probes], np.int64)
    tp, tn, tv = idx.arrays()
    out = pkval_lookup(tp, tn, tv, par, nam)
    ref = pkval_ref(tp, tn, tv, par.astype(np.int32),
                    nam.astype(np.uint32))
    assert out.shape == (n_probes,)
    assert (out == ref).all()
    # ... and both agree with the host index's own exact probes
    for i, (p, h) in enumerate(probes):
        assert int(out[i]) == idx.get(p, h)


def test_pkval_probe_bound_respected_across_growth():
    """The host index grows rather than placing an entry beyond
    MAX_PROBE, so the kernel's bounded probe NEVER misses a live key."""
    from repro.kernels.pkval.ref import pkval_ref
    idx, keys = _filled_index(2000, seed=4)
    tp, tn, tv = idx.arrays()
    par = np.array([k[0] for k in keys], np.int32)
    nam = np.array([k[1] for k in keys], np.uint32)
    out = pkval_ref(tp, tn, tv, par, nam)
    want = np.array([k[2] for k in keys], np.int32)
    assert (out == want).all()


def test_pkval_empty_and_padding():
    from repro.kernels.pkval.ops import pkval_lookup
    idx, _ = _filled_index(10, seed=5)
    tp, tn, tv = idx.arrays()
    assert pkval_lookup(tp, tn, tv, np.zeros(0, np.int64),
                        np.zeros(0, np.int64)).shape == (0,)
    # non-power-of-two probe counts pad with always-miss parents
    out = pkval_lookup(tp, tn, tv, np.array([123456789], np.int64),
                       np.array([7], np.int64))
    assert out.shape == (1,) and int(out[0]) == EMPTY


# ---------------------------------------------------------------------------
# hintchain
# ---------------------------------------------------------------------------

def _chain_fixture(seed=0, n=64, d=5):
    """Build client/fallback indexes over a synthetic tree plus [n, d]
    chain matrices with known expected resolutions."""
    rng = np.random.default_rng(seed)
    client = HashIndex()
    fall = HashIndex()
    # a two-level namespace: /dirX/fileY with ids laid out predictably
    dirs = {}
    for x in range(20):
        did = 10 + x
        dirs[x] = did
        (client if x % 2 == 0 else fall).set(1, name_hash32(f"d{x}"), did)
    for x in range(20):
        for y in range(6):
            fid = 1000 + x * 10 + y
            (client if y % 3 == 0 else fall).set(
                dirs[x], name_hash32(f"f{y}"), fid)
    nam = np.zeros((n, d), np.uint32)
    dep = np.zeros(n, np.int32)
    for i in range(n):
        x = int(rng.integers(0, 24))          # some dirs don't exist
        y = int(rng.integers(0, 8))           # some files don't exist
        nam[i, 0] = name_hash32(f"d{x}")
        nam[i, 1] = name_hash32(f"f{y}")
        dep[i] = 2
    return client, fall, nam, dep


@pytest.mark.parametrize("seed", [0, 3])
def test_hintchain_kernel_matches_ref(seed):
    from repro.kernels.hintchain.ops import hintchain_resolve
    from repro.kernels.hintchain.ref import hintchain_ref
    client, fall, nam, dep = _chain_fixture(seed=seed, n=70, d=5)
    cp, cn, cv = client.arrays()
    fp, fn, fv = fall.arrays()
    childs, srcs = hintchain_resolve((cp, cn, cv), (fp, fn, fv), nam, dep)
    rch, rsr = hintchain_ref(cp, cn, cv, fp, fn, fv, nam, dep)
    assert childs.shape == nam.shape
    assert (childs == rch).all()
    assert (srcs == rsr).all()


def test_hintchain_resolution_semantics():
    """Spot-check the (child, src) encoding against hand walks: client
    precedence, fallback hits, chain stop at first miss, dead ops."""
    from repro.kernels.hintchain.ref import hintchain_ref
    client, fall, nam, dep = _chain_fixture(seed=1, n=40, d=5)
    cp, cn, cv = client.arrays()
    fp, fn, fv = fall.arrays()
    childs, srcs = hintchain_ref(cp, cn, cv, fp, fn, fv, nam, dep)
    for i in range(nam.shape[0]):
        parent = 1
        alive = True
        for d in range(int(dep[i])):
            cval = client.get(parent, int(nam[i, d]))
            fval = fall.get(parent, int(nam[i, d]))
            want = cval if cval != EMPTY else fval
            if not alive:
                assert int(childs[i, d]) == -2
                continue
            if want > 0:
                assert int(childs[i, d]) == want
                assert int(srcs[i, d]) == (0 if cval > 0 else 1)
                parent = want
            else:
                assert int(childs[i, d]) == EMPTY
                assert int(srcs[i, d]) == -1
                alive = False
        for d in range(int(dep[i]), nam.shape[1]):
            assert int(childs[i, d]) == -2


def test_hintchain_ambig_passthrough(monkeypatch):
    """A poisoned client bucket must surface AMBIG, not a fake hit, and
    must NOT fall through to the fallback table."""
    from repro.kernels.hintchain.ref import hintchain_ref
    client = HashIndex()
    client.set(1, 42, AMBIG)
    fall = HashIndex()
    fall.set(1, 42, 777)
    nam = np.array([[42]], np.uint32)
    dep = np.array([1], np.int32)
    childs, srcs = hintchain_ref(*client.arrays(), *fall.arrays(),
                                 nam, dep)
    assert int(childs[0, 0]) == AMBIG
    assert int(srcs[0, 0]) == -1


def test_hintchain_empty_window():
    from repro.kernels.hintchain.ops import hintchain_resolve
    idx = HashIndex()
    childs, srcs = hintchain_resolve(idx.arrays(), idx.arrays(),
                                     np.zeros((0, 4), np.uint32),
                                     np.zeros(0, np.int32))
    assert childs.shape == (0, 4) and srcs.shape == (0, 4)


# ---------------------------------------------------------------------------
# _KernelProbe fallback & recovery (per-family gates)
# ---------------------------------------------------------------------------

def test_kernel_probe_fallback_and_bounded_recovery():
    probe = _KernelProbe(reprobe_every=4)
    calls = {"kern": 0, "fall": 0}

    def bad_kernel():
        calls["kern"] += 1
        raise RuntimeError("accelerator hiccup")

    def fallback():
        calls["fall"] += 1
        return "fallback"

    out, used = _with_phash_kernel(bad_kernel, fallback, n_keys=100,
                                   min_batch=2, probe=probe)
    assert out == "fallback" and not used and probe.failures == 1
    # while latched, eligible calls use the fallback without probing...
    for _ in range(3):
        out, used = _with_phash_kernel(bad_kernel, fallback, n_keys=100,
                                       min_batch=2, probe=probe)
        assert not used
    assert calls["kern"] == 1
    # ...until the bounded re-probe window elapses and the (recovered)
    # kernel is tried again
    def good_kernel():
        calls["kern"] += 1
        return "kernel"

    out, used = _with_phash_kernel(good_kernel, fallback, n_keys=100,
                                   min_batch=2, probe=probe)
    assert out == "kernel" and used and probe.failures == 0


def test_kernel_probe_families_are_independent():
    columnar._pkval_probe.failed()
    try:
        assert not columnar._pkval_probe.usable()
        assert columnar._hintchain_probe.usable()
    finally:
        columnar._pkval_probe.succeeded()


def test_lower_trace_fused_survives_kernel_failure(monkeypatch):
    """If the hintchain kernel raises, the probe latches the numpy oracle
    and the fused lowering still returns the exact Python-walk result."""
    from repro.core import (NamenodeCluster, format_fs,
                            materialize_namespace)
    from repro.core.batch_planner import HintResolver, MultiCacheResolver
    from repro.core.columnar import ColumnarMetadataStore, lower_trace_fused
    from repro.core.hint_cache import InodeHintCache
    from repro.core.workload import (NamespaceSpec, SyntheticNamespace,
                                     lower_trace, make_spotify_trace)
    import repro.kernels.hintchain.ops as hc_ops

    store = ColumnarMetadataStore(n_datanodes=4)
    format_fs(store)
    cluster = NamenodeCluster(store, 1)
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=8, files_per_dir=4)
    materialize_namespace(cluster.namenodes[0], ns)
    wops = make_spotify_trace(ns, 60, seed=2)

    def resolver():
        return HintResolver(InodeHintCache(),
                            MultiCacheResolver.of_cluster(cluster))

    monkeypatch.setattr(columnar, "HINTCHAIN_MIN_BATCH", 2)

    def boom(*a, **kw):
        raise RuntimeError("no accelerator")

    monkeypatch.setattr(hc_ops, "hintchain_resolve", boom)
    r1 = resolver()
    ct_fused, used = lower_trace_fused(wops, r1)
    assert not used                       # oracle fallback, not the kernel
    r2 = resolver()
    ct_ref = lower_trace(wops, r2)
    assert ct_fused.resolved == ct_ref.resolved
    assert ct_fused.pks == ct_ref.pks
    assert ct_fused.target_ids == ct_ref.target_ids
    assert (ct_fused.depths == ct_ref.depths).all()
    assert (ct_fused.hint_ids == ct_ref.hint_ids).all()
    assert (r1.hits, r1.fallback_hits, r1.misses) \
        == (r2.hits, r2.fallback_hits, r2.misses)
    columnar._hintchain_probe.succeeded()   # don't leak latched state
