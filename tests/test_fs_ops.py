"""Integration tests: FS operations — semantics + Table 3 round trips."""
import pytest

from repro.core import (FileAlreadyExists, FileNotFound, HopsFSOps,
                        MetadataStore, format_fs)
from repro.core.costmodel import create_depth10_roundtrips, table3


@pytest.fixture
def fs():
    store = MetadataStore(n_datanodes=4)
    format_fs(store)
    return HopsFSOps(store, 0)


@pytest.fixture
def deep(fs):
    d = "/1/" + "/".join(f"d{i}" for i in range(2, 10))  # depth 9 dirs
    fs.mkdirs(d)
    return d


def cold(fs):
    return HopsFSOps(fs.store, 1, use_cache=False)


class TestSemantics:
    def test_create_read_delete(self, fs, deep):
        f = deep + "/f"
        fid = fs.create(f).value
        assert fs.stat(f).value["id"] == fid
        bid = fs.add_block(f).value
        fs.complete_block(f, bid, size=128)
        locs = fs.get_block_locations(f).value
        assert locs[0]["block"] == bid and locs[0]["locations"]
        fs.delete_file(f)
        with pytest.raises(FileNotFound):
            fs.stat(f)

    def test_no_duplicate_create(self, fs, deep):
        fs.create(deep + "/x")
        with pytest.raises(FileAlreadyExists):
            fs.create(deep + "/x")

    def test_rename_moves_shard(self, fs, deep):
        f = deep + "/src"
        fid = fs.create(f).value
        fs.mkdir(deep + "/sub")
        fs.rename_file(f, deep + "/sub/dst")
        assert fs.stat(deep + "/sub/dst").value["id"] == fid
        with pytest.raises(FileNotFound):
            fs.stat(f)
        # composite PK changed -> row lives on the NEW parent's shard
        t = fs.store.table("inode")
        sub_id = fs.stat(deep + "/sub").value["id"]
        assert t.get((sub_id, "dst")) is not None

    def test_listing_and_summary(self, fs, deep):
        for i in range(5):
            fs.create(f"{deep}/f{i}")
        assert fs.listing(deep).value == [f"f{i}" for i in range(5)]
        assert fs.content_summary(deep).value["children"] == 5

    def test_hint_cache_self_heals_after_rename(self, fs, deep):
        """§5.1.1: stale hints fail PK validation, resolution falls back."""
        f = deep + "/victim"
        fs.create(f)
        other = HopsFSOps(fs.store, 2)     # second NN with its own cache
        other.stat(f)                       # warm its cache
        fs.rename_file(f, deep + "/renamed")
        with pytest.raises(FileNotFound):
            other.stat(f)                   # stale hint -> miss -> NotFound
        assert other.stat(deep + "/renamed").value["id"]

    def test_block_report(self, fs, deep):
        f = deep + "/data"
        fs.create(f)
        bids = []
        for i in range(3):
            b = fs.add_block(f).value
            fs.complete_block(f, b, size=1)
            bids.append(b)
        res = fs.process_block_report(7, bids + [99999])
        inv = fs.store.table("inv").scan_all(lambda r: True)
        assert any(r["block_id"] == 99999 for r in inv)
        reps = fs.store.table("replica").scan_all(
            lambda r: r["datanode_id"] == 7)
        assert len(reps) == 3


class TestTable3Costs:
    """Measured round trips == paper Table 3 (±1 where the paper's own
    formulas are asymmetric; see EXPERIMENTS.md)."""

    CASES = [
        ("create", lambda fs, d: fs.create(d + "/n1"), True, 0),
        ("read", lambda fs, d: fs.get_block_locations(d + "/f"), True, 0),
        ("stat", lambda fs, d: fs.stat(d + "/f"), True, 0),
        ("mkdir", lambda fs, d: fs.mkdir(d + "/m1"), True, 0),
        ("addblk", lambda fs, d: fs.add_block(d + "/f"), True, 0),
        ("chmod", lambda fs, d: fs.chmod_file(d + "/f", 0o600), True, 0),
        ("delete", lambda fs, d: fs.delete_file(d + "/f"), True, 0),
    ]

    @pytest.mark.parametrize("op,fn,empty,tol", CASES)
    def test_cache_hit_costs(self, fs, deep, op, fn, empty, tol):
        fs.create(deep + "/f")
        fs.get_block_locations(deep + "/f")      # warm
        measured = fn(fs, deep).cost.round_trips
        expect = table3(op, 10, cached=True, empty_file=empty).total
        assert abs(measured - expect) <= tol, (op, measured, expect)

    @pytest.mark.parametrize("op,fn,tol", [
        ("create", lambda fs, d: fs.create(d + "/n2"), 0),
        ("read", lambda fs, d: fs.get_block_locations(d + "/f"), 0),
        ("stat", lambda fs, d: fs.stat(d + "/f"), 0),
        ("mkdir", lambda fs, d: fs.mkdir(d + "/m2"), 0),
        ("addblk", lambda fs, d: fs.add_block(d + "/f"), 0),
        ("chmod", lambda fs, d: fs.chmod_file(d + "/f", 0o640), 0),
        ("delete", lambda fs, d: fs.delete_file(d + "/f"), 1),
    ])
    def test_cache_miss_costs(self, fs, deep, op, fn, tol):
        fs.create(deep + "/f")
        c = cold(fs)
        measured = fn(c, deep).cost.round_trips
        expect = table3(op, 10, cached=False, empty_file=True).total
        assert abs(measured - expect) <= tol, (op, measured, expect)

    def test_cache_hit_cost_is_depth_independent(self, fs):
        """The structural claim behind §5.1: hint hits remove the
        depth-proportional round trips."""
        costs = []
        for n in (3, 6, 12):
            d = "/" + "/".join(f"p{n}x{i}" for i in range(n - 1))
            fs.mkdirs(d)
            fs.create(d + "/f")
            costs.append(fs.get_block_locations(d + "/f").cost.round_trips)
        assert costs[0] == costs[1] == costs[2]

    def test_worked_example(self):
        """§7.7: create at depth 10 = 26 RTs cold, 11 warm, ≈58% saved."""
        ex = create_depth10_roundtrips()
        assert ex == {"no_cache": 26, "cache": 11, "saved": 15,
                      "improvement_pct": 58}

    def test_ppis_conditional_on_file_size(self, fs, deep):
        f = deep + "/grow"
        fs.create(f)
        assert fs.get_block_locations(f).cost.ppis == 1      # empty: 1
        b = fs.add_block(f).value
        fs.complete_block(f, b, size=10)
        assert fs.get_block_locations(f).cost.ppis == 5      # full: 5
