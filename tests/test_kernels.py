"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp
oracle, plus oracle-vs-explicit-recurrence cross-checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _tol(dtype):
    return ATOL[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,KV,hd,bq,bk", [
    (1, 128, 4, 4, 32, 64, 64),       # MHA
    (2, 256, 8, 2, 64, 128, 64),      # GQA, rectangular blocks
    (1, 512, 4, 1, 32, 128, 128),     # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 64])
def test_flash_attention_sweep(B, S, H, KV, hd, bq, bk, dtype, window):
    from repro.kernels.flash_attention.kernel import flash_attention_fwd
    from repro.kernels.flash_attention.ref import attention_ref
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out = flash_attention_fwd(q, k, v, causal=True, window=window,
                              block_q=bq, block_k=bk)
    ref = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=_tol(dtype), rtol=1e-2)


def test_flash_attention_grad_matches_ref():
    from repro.kernels.flash_attention import ops
    from repro.kernels.flash_attention.ref import attention_ref
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 16))
    k = jax.random.normal(ks[1], (1, 128, 2, 16))
    v = jax.random.normal(ks[2], (1, 128, 2, 16))
    g1 = jax.grad(lambda q_: ops.flash_attention(q_, k, v).sum())(q)
    g2 = jax.grad(lambda q_: attention_ref(q_, k, v).sum())(q)
    np.testing.assert_allclose(g1, g2, atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# mamba2 SSD
# ---------------------------------------------------------------------------

def _ssd_explicit(x, dt, A, Bc, Cc):
    """Explicit per-timestep recurrence (ground truth)."""
    B, S, H, hd = x.shape
    N = Bc.shape[-1]
    h = jnp.zeros((B, H, hd, N))
    ys = []
    for t in range(S):
        a = jnp.exp(dt[:, t] * A[None])                       # [B,H]
        h = h * a[..., None, None] + jnp.einsum(
            "bhp,bn,bh->bhpn", x[:, t], Bc[:, t], dt[:, t])
        ys.append(jnp.einsum("bn,bhpn->bhp", Cc[:, t], h))
    return jnp.stack(ys, 1), h


@pytest.mark.parametrize("B,S,H,hd,N,chunk", [
    (1, 64, 2, 8, 4, 16), (2, 128, 3, 16, 8, 32), (1, 96, 1, 8, 16, 32),
])
def test_ssd_chunked_matches_explicit(B, S, H, hd, N, chunk):
    from repro.models.mamba2 import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, S, H, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bc = jax.random.normal(ks[3], (B, S, N))
    Cc = jax.random.normal(ks[4], (B, S, N))
    y1, h1 = ssd_chunked(x, dt, A, Bc, Cc, chunk=chunk)
    y2, h2 = _ssd_explicit(x, dt, A, Bc, Cc)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(h1, h2, atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,hd,N", [(2, 128, 3, 16, 8), (1, 64, 2, 8, 4)])
def test_ssd_kernel_vs_ref(B, S, H, hd, N, dtype):
    from repro.kernels.mamba2_ssd.kernel import ssd_fwd
    from repro.kernels.mamba2_ssd.ref import ssd_ref
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(
        jnp.float32)
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bc = jax.random.normal(ks[3], (B, S, N), dtype)
    Cc = jax.random.normal(ks[4], (B, S, N), dtype)
    y1, h1 = ssd_fwd(x, dt, A, Bc, Cc, chunk=32)
    y2, h2 = ssd_ref(x, dt, A, Bc, Cc, chunk=32)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               atol=_tol(dtype) * 4, rtol=2e-2)
    np.testing.assert_allclose(h1, h2, atol=_tol(dtype) * 4, rtol=2e-2)


def test_ssd_decode_step_matches_scan():
    from repro.models.mamba2 import ssd_chunked, ssd_decode_step
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    B, S, H, hd, N = 1, 17, 2, 8, 4
    x = jax.random.normal(ks[0], (B, S, H, hd))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bc = jax.random.normal(ks[3], (B, S, N))
    Cc = jax.random.normal(ks[4], (B, S, N))
    h = jnp.zeros((B, H, hd, N))
    ys = []
    for t in range(S):
        y, h = ssd_decode_step(x[:, t:t+1], dt[:, t:t+1], A,
                               Bc[:, t:t+1], Cc[:, t:t+1], h)
        ys.append(y[:, 0])
    y_dec = jnp.stack(ys, 1)
    y_ref, h_ref = ssd_chunked(x, dt, A, Bc, Cc, chunk=17)
    np.testing.assert_allclose(y_dec, y_ref, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(h, h_ref, atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# rwkv6 WKV
# ---------------------------------------------------------------------------

def _wkv_explicit(r, k, v, w, u):
    B, S, H, hd = r.shape
    s = jnp.zeros((B, H, hd, hd))
    ys = []
    for t in range(S):
        rt, kt, vt, wt = r[:, t], k[:, t], v[:, t], w[:, t]
        y = jnp.einsum("bhc,bhcd->bhd", rt, s) + \
            jnp.einsum("bhc,bhc,bhd->bhd", rt * u[None], kt, vt)
        s = s * wt[..., None] + jnp.einsum("bhc,bhd->bhcd", kt, vt)
        ys.append(y)
    return jnp.stack(ys, 1), s


@pytest.mark.parametrize("B,S,H,hd,chunk", [
    (1, 64, 2, 8, 16), (2, 96, 1, 16, 32),
])
def test_wkv_chunked_matches_explicit(B, S, H, hd, chunk):
    from repro.models.rwkv6 import wkv6_chunked
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, hd))))
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    y1, s1 = wkv6_chunked(r, k, v, w, u, chunk=chunk)
    y2, s2 = _wkv_explicit(r, k, v, w, u)
    np.testing.assert_allclose(y1, y2, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(s1, s2, atol=1e-4, rtol=1e-3)


def test_wkv_strong_decay_is_finite():
    """Regression: data-dependent decay can underflow w to 0 in f32; the
    chunked form must stay finite (masked-exponent computation)."""
    from repro.models.rwkv6 import wkv6_chunked
    B, S, H, hd = 1, 64, 1, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    r = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    w = jnp.full((B, S, H, hd), 1e-45)            # flushed-to-zero decay
    u = jnp.ones((H, hd))
    y, s = wkv6_chunked(r, k, v, w, u, chunk=16)
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(s).all())


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv_kernel_vs_ref(dtype):
    from repro.kernels.rwkv6_scan.kernel import wkv6_fwd
    from repro.kernels.rwkv6_scan.ref import wkv6_ref
    B, S, H, hd = 2, 128, 3, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, H, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, H, hd), dtype)
    w = jnp.exp(-jnp.exp(jax.random.normal(
        ks[3], (B, S, H, hd)) * 0.5)).astype(jnp.float32)
    u = (jax.random.normal(ks[4], (H, hd)) * 0.1).astype(jnp.float32)
    y1, s1 = wkv6_fwd(r, k, v, w, u, chunk=32)
    y2, s2 = wkv6_ref(r, k, v, w, u, chunk=32)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               atol=_tol(dtype) * 4, rtol=2e-2)
    np.testing.assert_allclose(s1, s2, atol=_tol(dtype) * 4, rtol=2e-2)


def test_wkv_decode_step_matches_scan():
    from repro.models.rwkv6 import wkv6_chunked, wkv6_step
    B, S, H, hd = 1, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (B, S, H, hd)) * 0.3))
    u = jax.random.normal(ks[4], (H, hd)) * 0.1
    s = jnp.zeros((B, H, hd, hd))
    ys = []
    for t in range(S):
        y, s = wkv6_step(r[:, t:t+1], k[:, t:t+1], v[:, t:t+1],
                         w[:, t:t+1], u, s)
        ys.append(y[:, 0])
    y_ref, s_ref = wkv6_chunked(r, k, v, w, u, chunk=8)
    np.testing.assert_allclose(jnp.stack(ys, 1), y_ref, atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(s, s_ref, atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# grouped matmul + phash
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("E,C,D,F,bc,bf,bd", [
    (4, 64, 32, 48, 32, 16, 16), (2, 128, 64, 64, 64, 64, 32),
    (8, 32, 16, 16, 32, 16, 16),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gmm_sweep(E, C, D, F, bc, bf, bd, dtype):
    from repro.kernels.moe_gmm.kernel import gmm
    from repro.kernels.moe_gmm.ref import gmm_ref
    ks = jax.random.split(jax.random.PRNGKey(0), 2)
    x = jax.random.normal(ks[0], (E, C, D), dtype)
    w = jax.random.normal(ks[1], (E, D, F), dtype)
    out = gmm(x, w, block_c=bc, block_f=bf, block_d=bd)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(gmm_ref(x, w), np.float32),
                               atol=_tol(dtype) * D ** 0.5, rtol=2e-2)


def test_phash_chain_kernel_matches_ref_and_store():
    """The fused chain variant: component partitions, hint partitions and
    chain signatures agree with the numpy oracle, and both agree with the
    scalar store hash on partition placement."""
    from repro.core.store import _hash_key
    from repro.kernels.phash.ops import phash_chains
    from repro.kernels.phash.ref import phash_chain_ref
    rng = np.random.default_rng(3)
    n, d = 21, 7
    par = rng.integers(0, 2**31, (n, d))
    nam = rng.integers(0, 2**32, (n, d))
    hin = rng.integers(0, 2**31, n)
    dep = rng.integers(1, d + 1, n)
    comp, hint_parts, sigs = phash_chains(par, nam, hin, dep, 64)
    rcomp, rhint, rsig = phash_chain_ref(par, nam, hin, dep, 64)
    assert (comp == rcomp).all()
    assert (hint_parts == rhint).all()
    assert (sigs == rsig).all()
    assert all(hint_parts[i] == _hash_key(int(hin[i])) % 64
               for i in range(n))
    # identical chains hash identically; differing names do not
    c2, h2, s2 = phash_chains(par, nam, hin, dep, 64)
    assert (s2 == sigs).all()
    _c3, _h3, s3 = phash_chains(par, (nam + 1) & 0xFFFFFFFF, hin, dep, 64)
    assert (s3 != sigs).any()


def test_phash_kernel_matches_ref():
    from repro.kernels.phash.kernel import phash
    from repro.kernels.phash.ref import phash_ref
    keys = jnp.asarray((np.arange(8192, dtype=np.uint64) * 2654435761
                        % (2**31)).astype(np.int32))
    out = phash(keys, n_partitions=128, block_n=512)
    assert (np.asarray(out) == phash_ref(keys, 128)).all()
