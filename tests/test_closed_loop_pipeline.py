"""Closed-loop planned pipeline (ISSUE 5 tentpole).

Contract:
  1. **hint piggybacking** — every namenode response carries the
     ``(parent_id, name) -> inode_id`` resolutions its hint cache holds
     for the op's path(s) (``OpResult.hints``); ``DFSClient`` and the
     planned pipeline warm a real client-side ``InodeHintCache`` from
     them and invalidate on destructive ops, so client-side planning
     resolves from RESPONSES (namenode caches are only the fallback);
  2. **adaptive windows** — the planning window is a control variable:
     the ``WindowController`` grows it while round trips per op hold and
     shrinks it under conflict pinning, deterministically;
  3. **concurrent-mode lease-ordered dealing** — concurrent planned
     execution no longer pins every mutation: windows are execution
     barriers, same-key (same-file) block-write runs are never split
     across batches, and the final namespace equals sequential replay —
     including under adversarial same-file contention, where every
     non-holder block write is refused with ``LeaseConflict`` exactly as
     sequential execution refuses it;
  4. **piggybacked lease renewal** — any registered op executed by a live
     lease holder refreshes the lease stamp, so a steadily-writing client
     never trips the leader's lease recovery.
"""
import pytest

from repro.core import (BatchPlanner, DFSClient, PlannedRequestPipeline,
                        RequestPipeline, WindowController, WorkloadOp,
                        namespace_snapshot)
from repro.core.cluster_sim import BatchedHopsFSSim, profile_ops
from repro.core.workload import (NamespaceSpec, SyntheticNamespace,
                                 WRITE_HEAVY_MIX,
                                 make_block_contention_trace,
                                 make_spotify_trace)


# cluster construction lives in the shared make_cluster fixture
# (tests/conftest.py); make_cluster(2, dirs=("/w",)) is make_cluster(2, dirs=("/w",)) and
# _build(n) is make_cluster(n, namespace=True).


# ---------------------------------------------------------------------------
# 1. response hint piggybacking
# ---------------------------------------------------------------------------

def test_responses_carry_piggybacked_hints(make_cluster):
    """A namenode response's ``hints`` hold the full (parent_id, name) ->
    inode_id chain of the op's path, enough for a cold client to resolve
    the same path without ever reading a namenode cache."""
    _store, cluster = make_cluster(2, dirs=("/w",))
    nn = cluster.namenodes[0]
    nn.ops.mkdirs("/w/a/b")
    nn.ops.create("/w/a/b/f")
    res = nn.invoke(WorkloadOp("stat", "/w/a/b/f"))
    chain = {(p, n): i for p, n, i in res.hints}
    # walk the chain from the root: every component resolves
    from repro.core import ROOT_ID
    parent = ROOT_ID
    for name in ("w", "a", "b", "f"):
        assert (parent, name) in chain
        parent = chain[(parent, name)]
    assert parent == res.value["id"]


def test_dfs_client_cache_warms_from_responses_and_invalidates(make_cluster):
    """The facade's client cache warms from every response and drops
    entries on destructive ops — rename moves the mapping, delete removes
    it."""
    _store, cluster = make_cluster(2, dirs=("/w",))
    dfs = DFSClient(cluster)
    fid = dfs.create("/w/f")
    wid = dfs.stat("/w").inode_id
    assert dfs.hint_cache.peek(wid, "f") == fid
    dfs.rename("/w/f", "/w/g")
    assert dfs.hint_cache.peek(wid, "f") is None
    assert dfs.hint_cache.peek(wid, "g") == fid
    dfs.delete("/w/g")
    assert dfs.hint_cache.peek(wid, "g") is None
    assert dfs.hint_cache.invalidations >= 2


def test_client_cache_resolves_without_namenode_caches(make_cluster):
    """The closed-loop core claim: once warmed from responses, the client
    cache alone (namenode caches cleared = the fallback resolver is
    empty) still resolves paths for planning."""
    _store, cluster, ns = make_cluster(2, namespace=True)
    trace = [WorkloadOp("read", f) for f in ns.files[:40]]
    pipe = PlannedRequestPipeline(cluster, batch_size=8)
    pipe.run(trace)
    for nn in cluster.namenodes:
        nn.ops.cache.clear()           # kill the fallback entirely
    planner = BatchPlanner(cluster, batch_size=8,
                           client_cache=pipe.client_cache)
    planner.plan_window(trace, 0, len(trace))
    assert planner.report.planned_ops == len(trace)
    assert planner.report.client_hits > 0
    assert planner.report.client_fallback_hits == 0


def test_closed_loop_hit_rate_and_stale_telemetry(make_cluster):
    """Across windows the planner's probes shift onto the client cache
    (hit rate > 0), and the report carries staleness telemetry fields."""
    _store, cluster, ns = make_cluster(2, namespace=True)
    trace = make_spotify_trace(ns, 240, seed=5)
    pipe = PlannedRequestPipeline(cluster, batch_size=8, window=80)
    pipe.run(trace)
    rep = pipe.plan_report
    assert rep.windows >= 2
    assert rep.client_hits > 0
    assert rep.hint_hit_rate > 0.0
    assert rep.client_stale >= 0 and rep.client_invalidations >= 0
    # second replay of the same trace resolves almost entirely client-side
    hits0 = rep.client_hits
    pipe.run(trace)
    assert pipe.plan_report.client_hits > 0
    assert pipe.plan_report.client_hits >= hits0 // 2


# ---------------------------------------------------------------------------
# 2. adaptive window sizing
# ---------------------------------------------------------------------------

def test_window_controller_policy():
    c = WindowController(64, min_window=16, max_window=256)
    # amortization paying: grow to the cap
    assert c.observe(64, 0, 640) == 128
    assert c.observe(128, 0, 1280) == 256
    assert c.observe(256, 0, 2560) == 256
    # conflict-pin pressure: shrink
    assert c.observe(256, 128, 2560) == 128
    # round-trip regression: shrink
    assert c.observe(128, 0, 5000) == 64
    # clamped at the floor
    assert c.observe(64, 64, 640) == 32
    assert c.observe(32, 32, 320) == 16
    assert c.observe(16, 16, 160) == 16
    assert c.history[0] == 64 and c.history[-1] == 16


def test_adaptive_window_grows_on_clean_trace(make_cluster):
    _store, cluster, ns = make_cluster(2, namespace=True)
    trace = [WorkloadOp("read", ns.files[i % len(ns.files)])
             for i in range(240)]
    pipe = PlannedRequestPipeline(cluster, batch_size=8, window=48)
    pipe.run(trace)
    sizes = pipe.plan_report.window_sizes
    assert len(sizes) >= 2
    assert max(sizes) > sizes[0]           # the controller grew the window
    assert pipe.planner.controller.window > 48


def test_adaptive_window_shrinks_under_conflicts(make_cluster):
    """A pathological trace (every mutation collides on one path) drives
    the pin rate to ~1, and the controller backs the window off to its
    floor instead of speculating."""
    _store, cluster = make_cluster(2, dirs=("/w",))
    cluster.namenodes[0].ops.create("/w/hot")
    trace = [WorkloadOp("chmod_file", "/w/hot", args={"perm": 0o600})
             for _ in range(160)]
    pipe = PlannedRequestPipeline(cluster, batch_size=8, window=64)
    pipe.run(trace)
    assert pipe.planner.controller.window < 64
    sizes = pipe.plan_report.window_sizes
    assert sizes[-1] < sizes[0]


def test_des_mirrors_adaptive_window():
    profiles = profile_ops()
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=20)
    trace = make_spotify_trace(ns, 500, seed=11)
    from repro.core.workload import TraceReplay
    sim = BatchedHopsFSSim(n_namenodes=2, n_ndb=4, profiles=profiles,
                           batch_size=16, planned=True, adaptive=True,
                           seed=1)
    sim.start_clients(300, TraceReplay(trace))
    res = sim.run(0.1)
    assert res.completed > 0
    hist = sim.controller.history
    assert len(hist) > 1                       # the loop actually closed
    assert all(4 <= w <= 64 for w in hist)     # clamped to [bs/4, 4*bs]
    assert any(w != hist[0] for w in hist[1:])  # and actually adapted


# ---------------------------------------------------------------------------
# 3. concurrent-mode lease-ordered dealing
# ---------------------------------------------------------------------------

def test_concurrent_mode_no_longer_pins_all_mutations(make_cluster):
    """The lifted restriction: concurrent planned execution deals free
    mutations (and lease-ordered block-write runs) out of the ordered
    queue — grouped writes engage in concurrent mode too."""
    _store, cluster, ns = make_cluster(2, namespace=True)
    trace = make_spotify_trace(ns, 300, seed=5, mix=WRITE_HEAVY_MIX)
    pipe = PlannedRequestPipeline(cluster, batch_size=8, concurrent=True)
    stats = pipe.run(trace)
    assert stats.ok + stats.failed == len(trace)
    assert stats.batched_write_fraction > 0
    rep = pipe.plan_report
    assert rep.pinned_ops < rep.ops            # not everything was pinned


def test_planned_concurrent_write_heavy_state_and_write_batching(make_cluster):
    """The ISSUE acceptance bar: on the write-heavy mix, sequential /
    reactive / planned / planned+concurrent all converge to the same
    namespace; the concurrent mode's batched_write_fraction is no worse
    than deterministic planned mode's and it beats reactive on round
    trips."""
    ns_ref = SyntheticNamespace(NamespaceSpec(), n_dirs=16, files_per_dir=4)
    trace = make_spotify_trace(ns_ref, 400, seed=5, mix=WRITE_HEAVY_MIX)

    def build():
        return make_cluster(4, namespace=True)[:2]

    store_seq, cl = build()
    RequestPipeline(cl, batch_size=1).run(trace)
    store_rea, cl = build()
    rea = RequestPipeline(cl, batch_size=16).run(trace)
    store_pln, cl = build()
    pln = PlannedRequestPipeline(cl, batch_size=16).run(trace)
    store_cc, cl = build()
    cc_pipe = PlannedRequestPipeline(cl, batch_size=16, concurrent=True)
    cc = cc_pipe.run(trace)
    snap = namespace_snapshot(store_seq)
    assert snap == namespace_snapshot(store_rea)
    assert snap == namespace_snapshot(store_pln)
    assert snap == namespace_snapshot(store_cc)
    assert cc.ok + cc.failed == len(trace)
    # concurrent mode batches block writes at least as well as
    # deterministic planned mode (identical plans; small slack for
    # stale-hint fallback differences under real concurrency)
    assert cc.batched_write_fraction >= pln.batched_write_fraction - 0.01
    assert cc.batched_write_fraction > 0.022       # the PR 3/4 bar
    assert cc.total_cost.round_trips < rea.total_cost.round_trips
    assert cc_pipe.plan_report.lease_ordered_ops > 0


def test_concurrent_same_file_block_runs_stay_ordered(make_cluster):
    """A hot file growing by 24 blocks while other files churn, executed
    by the CONCURRENT planned pipeline: block indices must come out
    exactly 0..23 — any cross-worker interleaving of the same-file run
    would duplicate or skip an index."""
    store, cluster = make_cluster(2, dirs=("/w",))
    nn = cluster.namenodes[0]
    nn.ops.create("/w/hot")
    for i in range(4):
        nn.ops.create(f"/w/cold{i}")
    hot_id = nn.ops.stat("/w/hot").value["id"]
    trace = []
    for i in range(24):
        trace.append(WorkloadOp("add_block", "/w/hot"))
        trace.append(WorkloadOp("add_block", f"/w/cold{i % 4}"))
        trace.append(WorkloadOp("read", f"/w/cold{i % 4}"))
    stats = PlannedRequestPipeline(cluster, batch_size=8,
                                   concurrent=True).run(trace)
    assert stats.failed == 0
    rows = store.table("block").scan_all(
        lambda r: r["inode_id"] == hot_id)
    assert sorted(r["index"] for r in rows) == list(range(24))


def test_interleaved_same_partition_block_runs_stay_atomic(make_cluster):
    """Two files hashing to the SAME partition with interleaved add_block
    runs: the (partition, type, i) sort alone would leave each file's run
    non-contiguous, letting the chunk cut split it across batches (and
    potentially slots). The key-anchored deal must put each file's whole
    run into exactly one batch — the atomic unit of per-file ordering —
    and concurrent replay must produce exact block indices."""
    store, cluster = make_cluster(2, dirs=("/w",))
    nn = cluster.namenodes[0]
    t = store.table("inode")
    by_part = {}
    pair = None
    for i in range(64):
        p = f"/w/f{i:02d}"
        fid = nn.ops.create(p).value
        part = t.partition_of(fid)
        if part in by_part:
            pair = (by_part[part], p)
            break
        by_part[part] = p
    assert pair is not None, "no partition collision in 64 files"
    a, b = pair
    trace = []
    for _ in range(6):                       # interleave the two runs
        trace.append(WorkloadOp("add_block", a))
        trace.append(WorkloadOp("add_block", b))
    planner = BatchPlanner(cluster, batch_size=4)
    batches = planner.plan_window(trace, 0, len(trace))
    for path in (a, b):
        homes = {bi for bi, bt in enumerate(batches)
                 for i in bt.indices if trace[i].path == path}
        assert len(homes) == 1               # whole run in ONE batch
    for bt in batches:                       # per-key submission order
        assert not bt.ordered
        for path in (a, b):
            idxs = [i for i in bt.indices if trace[i].path == path]
            assert idxs == sorted(idxs)
    stats = PlannedRequestPipeline(cluster, batch_size=4,
                                   concurrent=True).run(trace)
    assert stats.failed == 0
    for path in (a, b):
        fid = nn.ops.stat(path).value["id"]
        rows = store.table("block").scan_all(
            lambda r: r["inode_id"] == fid)
        assert sorted(r["index"] for r in rows) == list(range(6))


def test_same_file_contention_concurrent_equals_sequential(make_cluster):
    """The ISSUE satellite: two clients interleaving append / add_block /
    complete_block on ONE file. The non-holder is refused with
    ``LeaseConflict`` on every attempt, the outcome stream matches
    sequential replay exactly (contending ops pin to submission order),
    and the final namespace is identical."""
    trace = make_block_contention_trace("/w/f", 6)
    store_seq, cluster_seq = make_cluster(2, dirs=("/w",))
    cluster_seq.namenodes[0].ops.create("/w/f", client="c1")
    seq = RequestPipeline(cluster_seq, batch_size=1).run(trace)
    store_cc, cluster_cc = make_cluster(2, dirs=("/w",))
    cluster_cc.namenodes[0].ops.create("/w/f", client="c1")
    cc = PlannedRequestPipeline(cluster_cc, batch_size=8,
                                concurrent=True).run(trace)
    assert [(o.ok, o.error) for o in cc.outcomes] == \
           [(o.ok, o.error) for o in seq.outcomes]
    # the admission control actually fired: every c2 op conflicts
    conflicts = [o for o in cc.outcomes if o.error == "LeaseConflict"]
    assert len(conflicts) == 6 * 3                 # all of c2's attempts
    assert namespace_snapshot(store_cc) == namespace_snapshot(store_seq)


# ---------------------------------------------------------------------------
# 4. piggybacked lease renewal
# ---------------------------------------------------------------------------

def test_steady_writer_never_trips_lease_recovery(make_cluster):
    """ROADMAP PR-4 follow-up: a client that keeps WRITING (block ops)
    without ever calling renew_lease stays live — every registered op it
    executes refreshes its lease stamp, so the leader's recovery sweep
    finds nothing to reclaim."""
    store, cluster = make_cluster(2, dirs=("/w",))
    dfs = DFSClient(cluster)
    dfs.create("/w/f", client="c1")
    limit = cluster.namenodes[0].ops.lease_limit
    for i in range(4 * (limit + 1)):
        cluster.tick()                       # clock marches well past limit
        dfs.add_block("/w/f", client="c1")   # writing IS the heartbeat
        assert cluster.recover_leases() == 0
    lease = store.table("lease").get(("c1",))
    assert lease is not None
    assert lease["last_renewed"] == cluster.election.now
    # ... and once the writer actually stops, expiry works as before
    for _ in range(limit + 2):
        cluster.tick()
    assert cluster.recover_leases() >= 1
    assert store.table("lease").get(("c1",)) is None


def test_lease_recover_rechecks_liveness_under_lock(make_cluster):
    """A holder that renewed between the leader's expiry scan and the
    recovery transaction (the piggybacked-touch race) must NOT be
    reclaimed: lease_recover re-reads the lease row under its exclusive
    lock and skips live holders."""
    store, cluster = make_cluster(2, dirs=("/w",))
    dfs = DFSClient(cluster)
    dfs.create("/w/f", client="c1")
    limit = cluster.namenodes[0].ops.lease_limit
    for _ in range(limit + 2):
        cluster.tick()                        # c1 looks expired...
    assert cluster.namenodes[0].ops.expired_lease_holders() == ["c1"]
    cluster.namenodes[0].ops.touch_lease("c1")   # ...but renews just now
    res = cluster.namenodes[0].ops.lease_recover("c1")
    assert res.value is None                  # skipped, not reclaimed
    assert store.table("lease").get(("c1",)) is not None
    assert cluster.recover_leases() == 0      # sweep agrees: nothing done
    row = store.table("inode").scan_index(
        "id", dfs.stat("/w/f").inode_id)[0]
    assert row["under_construction"] is True and row["client"] == "c1"


def test_touch_lease_only_refreshes_existing_holders(make_cluster):
    _store, cluster = make_cluster(2, dirs=("/w",))
    nn = cluster.namenodes[0]
    assert nn.ops.touch_lease("ghost") is False
    dfs = DFSClient(cluster)
    dfs.create("/w/f", client="c1")
    assert nn.ops.touch_lease("c1") is True
    # a failed op by another client must NOT stamp anything for it
    from repro.core import LeaseConflict
    with pytest.raises(LeaseConflict):
        dfs.add_block("/w/f", client="c2")
    assert _store.table("lease").get(("c2",)) is None
