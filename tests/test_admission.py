"""Overload-hardened request path (ISSUE 8).

Contract, layer by layer:

  1. **deadlines** ride :class:`WorkloadOp` on the election's logical
     clock: namenodes shed expired work (``DeadlineExpired``) instead of
     executing it, the planner never deals an op that cannot make its
     deadline, and every committed :class:`OpResult` carries the
     ``completed_at`` tick goodput is judged by;
  2. **weighted fair queueing** at namenode admission sheds, under queue
     pressure, hot-tenant reads first and lease-holding mutations never —
     a Zipf-hot tenant cannot starve cold ones;
  3. **retry budgets** bound fleet-wide retries to ~``refill_rate`` of
     the call rate across ALL middleware sharing the bucket, and every
     backoff sleep is injectable + equal-jittered (deterministic per
     seed);
  4. **circuit breakers** (closed → open → half-open probes) trip on
     transport-class failures only and steer the planner, the client
     selector, and the elastic pool's victim choice;
  5. **soft-limit lease takeover**: between the soft and hard lease
     limits a new writer may force recovery while the leader's sweep
     still waits for the hard limit;
  6. the gray-failure **overload bench** (one DELAY-slow namenode, Zipf
     tenants): protection must beat the naive pipeline on goodput and
     per-tenant p99 with ZERO completions past deadline, and recovery
     must land on the sequential oracle's namespace.
"""
import random
import sys
from pathlib import Path

import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from repro.core import (AdmissionController, BreakerBoard, CircuitBreaker,
                        DFSClient, DeadlineExpired, ElasticNamenodePool,
                        FileNotFound, LeaseConflict, NetworkPartition,
                        PlannedRequestPipeline, RetryBudget, WorkloadOp,
                        stamp_deadlines)
from repro.core.middleware import CallContext, compose, failover, txn_retry
from repro.core.namenode import Client
from repro.core.store import LockTimeout
from repro.core.workload import (NamespaceSpec, SyntheticNamespace,
                                 make_zipf_tenant_trace)


# ---------------------------------------------------------------------------
# 1. deadline propagation on the election clock
# ---------------------------------------------------------------------------

def test_stamp_deadlines_and_zipf_tenants():
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=8, files_per_dir=3)
    trace = make_zipf_tenant_trace(ns, 300, n_tenants=4, seed=3)
    stamp_deadlines(trace, now=5, budget=10, per_op=0.5)
    assert trace[0].deadline == 15
    assert trace[-1].deadline == 15 + int(299 * 0.5)
    assert all(a.deadline <= b.deadline
               for a, b in zip(trace, trace[1:]))
    counts = {}
    for w in trace:
        counts[w.tenant] = counts.get(w.tenant, 0) + 1
    assert set(counts) == {"t0", "t1", "t2", "t3"}
    # Zipf s=1.1: t0 is the hot tenant, t3 the coldest
    assert counts["t0"] > counts["t3"]


def test_invoke_sheds_expired_op(make_cluster):
    store, cluster = make_cluster(1, dirs=("/w",), files=("/w/f",))
    adm = AdmissionController(cluster.election).install(cluster)
    nn = cluster.namenodes[0]
    res = nn.invoke(WorkloadOp("read", "/w/f",
                               deadline=cluster.election.now + 2))
    assert res.completed_at == cluster.election.now
    for _ in range(3):
        cluster.tick()
    with pytest.raises(DeadlineExpired):
        nn.invoke(WorkloadOp("read", "/w/f",
                             deadline=cluster.election.now - 1))
    rep = adm.report()
    assert rep["admitted"] == 1 and rep["shed_deadline"] == 1
    adm.uninstall()
    assert nn.admission is None
    # uninstalled: deadlines are inert again (recovery re-drive path)
    nn.invoke(WorkloadOp("read", "/w/f",
                         deadline=cluster.election.now - 1))


def test_batch_sheds_expired_and_stamps_completed_at(make_cluster):
    store, cluster = make_cluster(1, dirs=("/w",), files=("/w/f",))
    AdmissionController(cluster.election).install(cluster)
    nn = cluster.namenodes[0]
    now = cluster.election.now
    wops = [WorkloadOp("read", "/w/f", deadline=now - 1),
            WorkloadOp("read", "/w/f", deadline=now + 5),
            WorkloadOp("mkdirs", "/w/d", deadline=now + 5),
            WorkloadOp("read", "/w/f")]           # deadline-free
    outs = nn.execute_batch(wops)
    assert not outs[0].ok and outs[0].error == "DeadlineExpired"
    assert outs[0].batched
    for oc, wop in zip(outs[1:], wops[1:]):
        assert oc.ok
        assert oc.result.completed_at == cluster.election.now
        assert (wop.deadline is None
                or oc.result.completed_at <= wop.deadline)


def test_planner_sheds_expired_before_dealing(make_cluster):
    """Client-side deadline awareness: an op that can no longer make its
    deadline is never dealt at all — no round trip, no namenode work."""
    store, cluster, ns = make_cluster(2, namespace=True)
    trace = make_zipf_tenant_trace(ns, 40, n_tenants=2, seed=3)
    now = cluster.election.now
    stamp_deadlines(trace, now=now, budget=1000)
    for w in trace[:7]:
        w.deadline = now - 1                      # expired at submission
    served_before = sum(nn.ops_served for nn in cluster.namenodes)
    pipe = PlannedRequestPipeline(cluster, batch_size=4, window=8,
                                  adaptive=False)
    stats = pipe.run(trace)
    shed = [oc for oc in stats.outcomes
            if not oc.ok and oc.error == "DeadlineExpired"]
    assert len(shed) == 7
    assert pipe.plan_report.deadline_shed == 7
    served = sum(nn.ops_served for nn in cluster.namenodes) - served_before
    assert served == len(trace) - 7               # shed ops cost nothing


# ---------------------------------------------------------------------------
# 2. weighted fair queueing + load shedding
# ---------------------------------------------------------------------------

def _warm(adm, tenant, n, op="read", path="/w/f"):
    """Admit ``n`` pressure-free ops so ``tenant`` accumulates vtime."""
    adm.observe_queue(0)
    adm.admit_batch([WorkloadOp(op, path, tenant=tenant)
                     for _ in range(n)])


def test_pressure_sheds_hot_tenant_reads_first(make_cluster):
    store, cluster = make_cluster(1)
    adm = AdmissionController(cluster.election, queue_capacity=4)
    _warm(adm, "hot", 9)
    _warm(adm, "cold", 1)
    # moderate pressure (not severe): ONLY over-share reads are sheddable
    adm.observe_queue(6)
    batch = ([WorkloadOp("read", "/w/f", tenant="hot")] * 4
             + [WorkloadOp("mkdirs", "/w/d", tenant="hot")]
             + [WorkloadOp("read", "/w/f", tenant="cold")] * 2)
    decisions = adm.admit_batch(batch)
    # max_shed = int((6-4)/6 * 7) = 2 — hot reads only, cold untouched
    assert decisions[:4].count("OverloadShed") == 2
    assert decisions[4] is None                   # mutation: not severe
    assert decisions[5:] == [None, None]          # cold tenant never shed
    assert adm.shed_pressure == 2


def test_severe_pressure_sheds_non_lease_mutations_too(make_cluster):
    store, cluster = make_cluster(1)
    adm = AdmissionController(cluster.election, queue_capacity=4,
                              severe_factor=2.0)
    _warm(adm, "hot", 9)
    _warm(adm, "cold", 1)
    adm.observe_queue(100)                        # severe: 100 > 2*4
    batch = ([WorkloadOp("read", "/w/f", tenant="hot")] * 2
             + [WorkloadOp("mkdirs", "/w/d", tenant="hot")] * 2
             + [WorkloadOp("create", "/w/n", tenant="hot",
                           args={"client": "c1"})] * 2
             + [WorkloadOp("read", "/w/f", tenant="cold")])
    decisions = adm.admit_batch(batch)
    assert decisions[:2] == ["OverloadShed"] * 2  # hot reads first
    assert decisions[2:4] == ["OverloadShed"] * 2  # then hot mutations
    # lease-holding mutations are NEVER pressure-shed
    assert decisions[4:6] == [None, None]
    assert decisions[6] is None                   # cold tenant never shed


def test_hottest_tenant_sheds_before_warm_tenant(make_cluster):
    store, cluster = make_cluster(1)
    adm = AdmissionController(cluster.election, queue_capacity=4)
    _warm(adm, "hottest", 12)
    _warm(adm, "warm", 8)
    _warm(adm, "cold", 1)
    adm.observe_queue(7)          # max_shed = int(3/7 * 3) = 1
    decisions = adm.admit_batch([
        WorkloadOp("read", "/w/f", tenant="warm"),
        WorkloadOp("read", "/w/f", tenant="hottest"),
        WorkloadOp("read", "/w/f", tenant="cold")])
    assert decisions == [None, "OverloadShed", None]


def test_zipf_skew_cannot_starve_cold_tenants(make_cluster):
    """The headline WFQ property: replay a Zipf s=1.1 tenant mix through
    admission under sustained pressure — the hot tenant absorbs the
    sheds, tenants at/below fair share are admitted untouched."""
    store, cluster = make_cluster(1)
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=8, files_per_dir=3)
    trace = make_zipf_tenant_trace(ns, 400, n_tenants=5, seed=11)
    adm = AdmissionController(cluster.election, queue_capacity=8)
    _warm(adm, "t0", 12)          # the hot tenant is already over share
    adm.observe_queue(40)
    for lo in range(0, len(trace), 16):
        adm.admit_batch(trace[lo:lo + 16])
    rep = adm.report()
    t = rep["tenants"]
    assert rep["shed_pressure"] > 0
    # admitted work equalizes across tenants despite a ~5x arrival skew:
    admitted = [t[f"t{k}"]["admitted"] for k in range(5)]
    assert min(admitted) > 0.8 * max(admitted)
    # ...while the shed burden lands on the hot tenants, monotonically
    sheds = [t[f"t{k}"]["shed"] for k in range(5)]
    assert sheds == sorted(sheds, reverse=True)
    assert sheds[0] > 10 * max(1, sheds[-1])      # t0 absorbs the pain
    # per-client and per-partition telemetry feed the bench report
    assert sum(rep["clients"].values()) == rep["admitted"]
    assert rep["hot_partitions"]


# ---------------------------------------------------------------------------
# 3. retry budgets + jittered, injectable backoff
# ---------------------------------------------------------------------------

def test_retry_budget_bucket_math():
    rb = RetryBudget(capacity=2.0, refill_rate=0.5)
    assert rb.try_spend() and rb.try_spend()
    assert not rb.try_spend()
    assert rb.denied == 1
    rb.note_call()
    rb.note_call()                # two calls deposit 1.0 token
    assert rb.try_spend()
    assert (rb.calls, rb.spent) == (2, 3)
    for _ in range(100):
        rb.note_call()            # deposits cap at capacity
    assert rb.tokens <= rb.capacity


def test_budget_caps_failover_retries():
    rb = RetryBudget(capacity=2.0, refill_rate=0.0)
    calls = [0]

    def terminal(ctx):
        calls[0] += 1
        raise NetworkPartition("unreachable")

    h = compose([failover(attempts=8, budget=rb)], terminal)
    with pytest.raises(NetworkPartition):
        h(CallContext(op="read"))
    assert calls[0] == 3          # first attempt + 2 budgeted retries
    assert (rb.spent, rb.denied) == (2, 1)


def test_budget_is_shared_across_middleware_layers():
    """One bucket, many retry loops: failover and txn_retry draw from the
    same tokens, so their attempt counters cannot multiply."""
    rb = RetryBudget(capacity=2.0, refill_rate=0.0)
    calls = [0]

    def terminal(ctx):
        calls[0] += 1
        raise LockTimeout("contended")

    h = compose([failover(attempts=8, budget=rb),
                 txn_retry(retries=5, backoff=0, budget=rb)], terminal)
    with pytest.raises(LockTimeout):
        h(CallContext(op="read"))
    assert calls[0] == 3
    assert (rb.spent, rb.denied) == (2, 1)


def test_equal_jitter_is_bounded_and_deterministic():
    def run(seed):
        sleeps = []

        def terminal(ctx):
            raise NetworkPartition("unreachable")

        h = compose([failover(attempts=4, backoff=0.01,
                              jitter=random.Random(seed),
                              sleep=sleeps.append)], terminal)
        with pytest.raises(NetworkPartition):
            h(CallContext(op="read"))
        return sleeps

    a = run(7)
    assert len(a) == 3            # no sleep after the final attempt
    for k, s in enumerate(a):
        base = 0.01 * (2 ** k)    # equal jitter: [base/2, base)
        assert 0.5 * base <= s < base
    assert run(7) == a            # same seed, same replay
    assert run(8) != a


def test_txn_retry_backoff_uses_injected_sleep():
    sleeps = []

    def terminal(ctx):
        raise LockTimeout("contended")

    h = compose([txn_retry(retries=2, backoff=0.005,
                           sleep=sleeps.append)], terminal)
    with pytest.raises(LockTimeout):
        h(CallContext(op="read"))
    assert sleeps == [0.005, 0.01]    # exponential, no jitter when unset


def test_dfs_client_wires_budget_and_deposits_per_call(make_cluster):
    store, cluster = make_cluster(1, dirs=("/w",))
    rb = RetryBudget()
    dfs = DFSClient(cluster, retry_budget=rb, sleep=lambda s: None)
    dfs.mkdirs("/w/x")
    dfs.stat("/w/x")
    assert rb.calls == 2 and rb.spent == 0


# ---------------------------------------------------------------------------
# 4. circuit breakers: state machine + routing integration
# ---------------------------------------------------------------------------

def test_circuit_breaker_state_machine():
    clock = [0]
    br = CircuitBreaker(failure_threshold=2, reset_after=5,
                        now=lambda: clock[0])
    assert br.routable() and br.state == "closed"
    br.record_failure()
    assert br.state == "closed"   # below threshold
    br.record_failure()
    assert br.state == "open" and br.trips == 1
    assert br.is_open and not br.routable()
    clock[0] = 5                  # reset_after elapsed on the clock
    assert not br.is_open and br.state == "half_open"
    assert br.routable()          # consumes the single probe slot
    assert not br.routable()      # probe budget spent
    br.record_failure()           # probe failed: reopen, fresh timer
    assert br.state == "open" and br.trips == 2
    clock[0] = 10
    assert br.routable()          # half-open again
    br.record_success()
    assert br.state == "closed" and br.routable()


def test_breaker_board_aggregates_per_namenode(make_cluster):
    store, cluster = make_cluster(2)
    board = BreakerBoard(cluster.election, failure_threshold=1)
    board.record(1, ok=False)
    assert board.is_open(1) and not board.is_open(0)
    assert board.open_ids() == [1]
    assert board.states() == {0: "closed", 1: "open"}
    assert board.trips == 1
    board.record(1, ok=True)
    assert board.open_ids() == []


def test_genuine_fs_outcomes_never_trip_breaker(make_cluster):
    store, cluster = make_cluster(1)
    board = BreakerBoard(cluster.election, failure_threshold=1)
    dfs = DFSClient(cluster, breakers=board)
    with pytest.raises(FileNotFound):
        dfs.stat("/nope")
    with pytest.raises(FileNotFound):
        dfs.stat("/still/nope")
    assert board.trips == 0 and board.states() == {0: "closed"}


def test_planner_deals_around_open_breaker(make_cluster):
    store, cluster, ns = make_cluster(3, namespace=True)
    board = BreakerBoard(cluster.election, failure_threshold=1)
    board.record(1, ok=False)                      # NN 1: tripped
    trace = make_zipf_tenant_trace(ns, 48, n_tenants=2, seed=5)
    pipe = PlannedRequestPipeline(cluster, batch_size=4, window=16,
                                  adaptive=False, breakers=board)
    stats = pipe.run(trace)
    assert cluster.namenodes[1].batches_executed == 0
    assert cluster.namenodes[0].batches_executed > 0
    assert cluster.namenodes[2].batches_executed > 0
    assert pipe.plan_report.breaker_rerouted > 0
    assert stats.ok == len(trace)


def test_client_pick_avoids_open_breaker(make_cluster):
    store, cluster = make_cluster(3)
    board = BreakerBoard(cluster.election, failure_threshold=1)
    board.record(0, ok=False)
    cli = Client(cluster, policy="random", seed=1, board=board)
    assert all(cli._pick().nn_id != 0 for _ in range(20))
    # whole fleet tripped: degrade to plain liveness, never strand a call
    board.record(1, ok=False)
    board.record(2, ok=False)
    assert cli._pick() is not None


def test_pool_scale_in_prefers_tripped_namenode(make_cluster):
    store, cluster = make_cluster(3)
    board = BreakerBoard(cluster.election, failure_threshold=1)
    pool = ElasticNamenodePool(cluster, min_namenodes=1, breakers=board)
    board.record(1, ok=False)
    ev = pool.scale_in("test")
    assert ev.nn_id == 1          # without the breaker it would retire 2
    assert not cluster.namenodes[1].alive


# ---------------------------------------------------------------------------
# 5. soft-limit lease takeover (HDFS soft/hard lease split)
# ---------------------------------------------------------------------------

def test_soft_limit_defaults_and_clamping(make_cluster):
    store, cluster = make_cluster(1)
    ops = cluster.namenodes[0].ops
    assert ops.lease_soft_limit == ops.lease_limit     # default: no window
    store, cluster = make_cluster(1, lease_limit=4, lease_soft_limit=99)
    assert cluster.namenodes[0].ops.lease_soft_limit == 4


def test_soft_limit_takeover_window(make_cluster):
    """soft < age <= hard: a NEW writer may force recovery or append-
    takeover, while the leader's sweep still waits for the hard limit."""
    store, cluster = make_cluster(1, dirs=("/w",), lease_limit=6,
                                  lease_soft_limit=2)
    nn = cluster.namenodes[0]
    nn.ops.create("/w/f", client="c1")
    nn.ops.create("/w/g", client="c1")
    for _ in range(2):
        cluster.tick()
    # within the soft limit the holder is fully protected
    with pytest.raises(LeaseConflict):
        nn.ops.recover_lease("/w/f", client="c2")
    with pytest.raises(LeaseConflict):
        nn.ops.append_file("/w/g", client="c2")
    for _ in range(2):
        cluster.tick()            # age 4: soft(2) < 4 <= hard(6)
    # the leader's sweep does NOT reclaim inside the hard limit...
    assert cluster.recover_leases() == 0
    assert store.table("lease").get(("c1",)) is not None
    # ...but a new writer's takeover ops may
    assert nn.ops.recover_lease("/w/f", client="c2").value is True
    fid = nn.ops.append_file("/w/g", client="c2").value
    assert fid > 0
    assert store.table("lease").get(("c2",)) is not None
    # append takeover re-leased /w/g to the new writer
    [row] = store.table("inode").scan_all(lambda r: r["name"] == "g")
    assert row["client"] == "c2" and row["under_construction"]


# ---------------------------------------------------------------------------
# 6. the gray-failure overload bench (miniature acceptance run)
# ---------------------------------------------------------------------------

def test_overload_bench_acceptance():
    """ISSUE 8 acceptance: skewed trace + one DELAY-slow namenode. The
    protected run beats the naive one on goodput and worst-tenant p99,
    completes NOTHING past its deadline, and recovery converges on the
    sequential oracle's namespace."""
    from benchmarks.trace_replay import overload_report
    r = overload_report(n_ops=320, batch_size=8, n_tenants=4)
    u, p = r["unprotected"], r["protected"]
    assert u["late_completions"] > 0              # the naive run suffers
    assert p["late_completions"] == 0             # exact, not statistical
    assert p["goodput_frac"] > u["goodput_frac"]
    assert (p["worst_tenant_p99_ticks"] < u["worst_tenant_p99_ticks"])
    assert r["breaker_trips"] >= 1
    assert r["planner_breaker_rerouted"] > 0
    assert r["admission"]["shed_deadline"] > 0
    assert r["state_matches_sequential"] is True
