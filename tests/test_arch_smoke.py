"""Per-architecture smoke tests (deliverable f): every assigned arch as a
REDUCED same-family config — one forward + one train step on CPU, asserting
output shapes and finiteness; plus a decode step against the cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import forward, init_cache_specs, init_params, param_specs
from repro.models.params import ParamSpec
from repro.parallel.sharding import MeshPolicy
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.step import train_step_fn

POLICY = MeshPolicy()
B, S = 2, 32


def make_batch(cfg, *, train=True):
    batch = {"tokens": jnp.zeros((B, S), jnp.int32)}
    if train:
        batch["labels"] = jnp.ones((B, S), jnp.int32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.ones((B, cfg.n_patches, cfg.d_model),
                                         jnp.bfloat16)
        batch["positions"] = jnp.zeros((B, S, 3), jnp.int32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.ones((B, cfg.n_patches, cfg.d_model),
                                   jnp.bfloat16)
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    published = {
        "qwen2_vl_7b": (28, 3584, 28, 4, 18944, 152064),
        "qwen3_moe_30b_a3b": (48, 2048, 32, 4, 768, 151936),
        "mixtral_8x22b": (56, 6144, 48, 8, 16384, 32768),
        "command_r_plus_104b": (64, 12288, 96, 8, 33792, 256000),
        "gemma3_12b": (48, 3840, 16, 8, 15360, 262144),
        "nemotron_4_340b": (96, 18432, 96, 8, 73728, 256000),
        "qwen1_5_4b": (40, 2560, 20, 20, 6912, 151936),
        "zamba2_2_7b": (54, 2560, 32, 32, 10240, 32000),
        "rwkv6_3b": (32, 2560, 40, 40, 8960, 65536),
        "seamless_m4t_medium": (24, 1024, 16, 16, 4096, 256256),
    }[arch]
    L, d, h, kv, ff, vocab = published
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
    assert (cfg.moe_d_ff or cfg.d_ff) == ff
    assert cfg.vocab_size == vocab
    if arch == "qwen3_moe_30b_a3b":
        assert cfg.n_experts == 128 and cfg.experts_per_token == 8
    if arch == "mixtral_8x22b":
        assert cfg.n_experts == 8 and cfg.experts_per_token == 2
        assert cfg.sliding_window
    if arch == "zamba2_2_7b":
        assert cfg.ssm_state == 64 and cfg.shared_attn_every == 6


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch, key):
    cfg = get_smoke_config(arch)
    params = init_params(param_specs(cfg), key)
    logits, _ = forward(params, make_batch(cfg, train=False), cfg=cfg,
                        policy=POLICY, mesh=None)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss_direction(arch, key):
    cfg = get_smoke_config(arch)
    params = init_params(param_specs(cfg), key)
    opt_state = adamw_init(params)
    batch = make_batch(cfg)
    opt = OptConfig(lr=1e-3, warmup_steps=0, total_steps=4)
    l0 = None
    for _ in range(2):
        params, opt_state, loss = train_step_fn(
            params, opt_state, batch, cfg=cfg, policy=POLICY, mesh=None,
            opt=opt)
        l0 = float(loss) if l0 is None else l0
    assert np.isfinite(float(loss))
    assert float(loss) <= l0 + 0.5      # same batch: should not explode


@pytest.mark.parametrize("arch", ["gemma3_12b", "qwen3_moe_30b_a3b",
                                  "zamba2_2_7b", "rwkv6_3b",
                                  "seamless_m4t_medium"])
def test_decode_step_with_cache(arch, key):
    cfg = get_smoke_config(arch)
    params = init_params(param_specs(cfg), key)
    specs = init_cache_specs(cfg, B, S)
    cache = jax.tree.map(
        lambda s: jnp.zeros(s.shape, jnp.bfloat16 if len(s.shape) >= 3
                            else jnp.float32),
        specs, is_leaf=lambda x: isinstance(x, ParamSpec))
    batch = {"tokens": jnp.ones((B, 1), jnp.int32)}
    logits, new_cache = forward(params, batch, cfg=cfg, policy=POLICY,
                                mesh=None, cache=cache,
                                cache_index=jnp.int32(3))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert jax.tree.structure(new_cache) == jax.tree.structure(cache)


def test_microbatched_grad_accumulation_matches(key):
    cfg = get_smoke_config("qwen1_5_4b")
    params = init_params(param_specs(cfg), key)
    batch = make_batch(cfg)
    opt = OptConfig(lr=1e-3, warmup_steps=0, total_steps=2)
    p1, _, l1 = train_step_fn(params, adamw_init(params), batch, cfg=cfg,
                              policy=POLICY, mesh=None, opt=opt,
                              microbatches=1)
    p2, _, l2 = train_step_fn(params, adamw_init(params), batch, cfg=cfg,
                              policy=POLICY, mesh=None, opt=opt,
                              microbatches=2)
    assert abs(float(l1) - float(l2)) < 5e-2
    d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                  b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert d < 5e-2


def test_moe_dense_vs_capacity_dispatch(key):
    """The EP/TP dispatch path must agree with the dense reference when
    capacity is not exceeded (single-device mesh -> dense path is used;
    here we call the internal dispatch helpers directly)."""
    import numpy as np
    from repro.models.moe import _dispatch, _combine, _router, moe_dense
    from repro.models import param_specs
    cfg = get_smoke_config("qwen3_moe_30b_a3b")
    specs = param_specs(cfg)
    params = init_params(specs, key)
    lp = jax.tree.map(lambda a: a[0], params["layers"])["moe"]
    x = jax.random.normal(key, (2, 16, cfg.d_model), jnp.float32)
    ref = moe_dense(lp, x, cfg)
    T = 2 * 16
    w, idx = _router(lp, x, cfg.experts_per_token)
    x2 = x.reshape(T, cfg.d_model)
    C = T * cfg.experts_per_token            # capacity ample: no drops
    buf, keep, pos, w2 = _dispatch(x2, w.reshape(T, -1),
                                   idx.reshape(T, -1), cfg.n_experts, C)
    assert bool(keep.all())
    from repro.models.moe import _expert_ffn
    y = _combine(_expert_ffn(lp, buf), idx.reshape(T, -1), pos, keep, w2)
    np.testing.assert_allclose(np.asarray(y.reshape(2, 16, -1)),
                               np.asarray(ref), atol=2e-4, rtol=1e-3)
