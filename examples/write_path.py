"""The write path end to end: leases, grouped block writes, recovery.

Walks the lease-ordered block-write path of docs/API.md:

  1. a client creates a file (taking its lease) and streams blocks
     through add_block/complete_block;
  2. a second writer is fenced off by LeaseConflict while the lease is
     live;
  3. the first client "dies" (stops renewing); the LEADER reclaims its
     lease against the shared liveness clock and the second writer's
     append proceeds;
  4. a write-heavy trace replays through the planned pipeline — block
     writes group into shared transactions (batched_write_fraction) while
     same-file block ops keep submission order.

  PYTHONPATH=src python examples/write_path.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (DFSClient, LeaseConflict, MetadataStore,
                        NamenodeCluster, format_fs, materialize_namespace,
                        namespace_snapshot)
from repro.core.workload import (NamespaceSpec, SyntheticNamespace,
                                 WRITE_HEAVY_MIX, make_spotify_trace)


def main() -> None:
    store = MetadataStore(n_datanodes=4)
    format_fs(store)
    cluster = NamenodeCluster(store, 2)
    dfs = DFSClient(cluster)

    # -- 1. stream a file in blocks under client "etl"'s lease ---------
    dfs.mkdirs("/w")
    dfs.create("/w/ingest.parquet", client="etl")
    for mib in (64, 64, 17):
        bid = dfs.add_block("/w/ingest.parquet", client="etl")
        dfs.complete_block("/w/ingest.parquet", bid, size=mib << 20,
                           client="etl")
    st = dfs.stat("/w/ingest.parquet")
    print(f"streamed {st.size >> 20} MiB in 3 blocks under etl's lease")

    # -- 2. a second writer is fenced off ------------------------------
    try:
        dfs.append("/w/ingest.parquet", client="compactor")
    except LeaseConflict as e:
        print(f"compactor fenced off: {type(e).__name__}: {e}")

    # -- 3. etl dies; the leader reclaims its lease --------------------
    limit = cluster.namenodes[0].ops.lease_limit
    for _ in range(limit + 2):
        cluster.tick()                    # etl never renews
    reclaimed = cluster.recover_leases()
    print(f"leader reclaimed {reclaimed} expired lease(s)")
    dfs.append("/w/ingest.parquet", client="compactor")
    print("compactor's append succeeded after recovery")

    # -- 4. a write-heavy trace through the planned pipeline -----------
    # fresh deployments per mode, so the comparison is apples to apples
    # (final-state equality across modes is asserted in
    # tests/test_lease_block_writes.py)
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=12, files_per_dir=4)
    trace = make_spotify_trace(ns, 300, seed=7, mix=WRITE_HEAVY_MIX)
    stats = {}
    snaps = {}
    for mode in ("sequential", "planned"):
        s = MetadataStore(n_datanodes=4)
        format_fs(s)
        cl = NamenodeCluster(s, 2)
        materialize_namespace(cl.namenodes[0], ns)
        client = DFSClient(cl)
        stats[mode] = client.run_trace(trace, batch_size=1) \
            if mode == "sequential" \
            else client.run_trace(trace, batch_size=16, planned=True)
        snaps[mode] = namespace_snapshot(s)
    seq, pln = stats["sequential"], stats["planned"]
    print(f"write-heavy replay: planned {pln.total_cost.round_trips} RTs "
          f"vs sequential {seq.total_cost.round_trips}, "
          f"batched writes {pln.batched_write_fraction:.3f}, "
          f"batched reads {pln.batched_read_fraction:.3f}")
    assert pln.batched_write_fraction > 0, "block writes did not group"
    assert snaps["sequential"] == snaps["planned"], "state diverged"
    print("ok")


if __name__ == "__main__":
    main()
