"""Serving example: batched requests through the continuous-batching
engine against a reduced model (the decode step that the decode_32k /
long_500k dry-run cells lower at production scale).

  PYTHONPATH=src python examples/serve_batched.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params, param_specs
from repro.serve import Request, ServeEngine


def main() -> None:
    cfg = get_smoke_config("gemma3_12b")     # local:global attention family
    params = init_params(param_specs(cfg), jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_batch=4, max_seq=64)

    prompts = [np.array([5, 7, 11]), np.array([2, 3]),
               np.array([13, 17, 19, 23]), np.array([29]),
               np.array([31, 37])]
    for rid, p in enumerate(prompts):
        eng.submit(Request(rid, p, max_new=6))
    done = eng.run(max_iters=64)
    for r in sorted(done, key=lambda r: r.rid):
        print(f"req {r.rid}: prompt {list(r.prompt)} -> {r.generated}")
    print(f"served {len(done)} requests with continuous batching "
          f"(max_batch=4, shared KV cache)")


if __name__ == "__main__":
    main()
