"""End-to-end training example: ~100M-param model, a few hundred steps,
with the full production substrate — registry-backed data pipeline,
HopsFS-backed checkpoint manifests, heartbeats/leader election, an injected
worker failure with elastic re-mesh, and a kill-resume demonstrating exact
restart from the metadata plane.

  PYTHONPATH=src python examples/train_e2e.py [--steps 300]

(~100M params: 12 layers x d=512 with a 32k vocab ~ 115M. On one CPU core a
few hundred steps at batch 8 x seq 128 takes tens of minutes; --steps 40 is
the default for a quick pass; CI smoke uses even fewer.)
"""
import argparse
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.ckpt import CheckpointManager
from repro.data import DataPipeline, synthetic_batch
from repro.launch.mesh import make_host_mesh
from repro.metaplane import MetadataPlane
from repro.models import init_params, param_specs
from repro.models.params import count_params
from repro.parallel.sharding import MeshPolicy
from repro.runtime import FleetRuntime
from repro.train.optimizer import OptConfig, adamw_init
from repro.train.step import make_train_step


def build_cfg():
    return get_config("qwen1_5_4b").derive(
        n_layers=12, d_model=512, n_heads=8, n_kv_heads=8, d_ff=1536,
        vocab_size=32768, name="qwen-100m")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    args = ap.parse_args()

    cfg = build_cfg()
    mesh = make_host_mesh()
    policy = MeshPolicy()
    specs = param_specs(cfg)
    print(f"model: {count_params(specs) / 1e6:.0f}M params")

    plane = MetadataPlane()
    fleet = FleetRuntime(plane, n_workers=8, model_axis=1)
    pipeline = DataPipeline(plane, "the-pile-mini", n_shards=32)
    ckpt_dir = tempfile.mkdtemp(prefix="repro-e2e-")
    ckpt = CheckpointManager(ckpt_dir, plane, "e2e", keep=2)

    params = init_params(specs, jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    opt = OptConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    step_fn = jax.jit(make_train_step(cfg, policy, mesh, opt=opt))

    half = args.steps // 2
    t0 = time.time()
    losses = []
    step = 0
    while step < args.steps:
        fleet.tick()
        plane.tick()
        if step == half:
            # checkpoint, then simulate a crash + restart-from-manifest
            ckpt.save(step, params, opt_state)
            print(f"[{step}] checkpoint committed; simulating crash...")
            del params, opt_state
            restored = ckpt.restore_latest()
            assert restored is not None and restored[0] == step
            _, p_np, o_np = restored
            params = jax.tree.map(jnp.asarray, p_np)
            opt_state = jax.tree.map(jnp.asarray, o_np)
            fleet.fail_worker(2)
            fleet.tick()
            print(f"[{step}] restored from manifest; worker 2 lost -> "
                  f"mesh {fleet.maybe_remesh()}")
        worker = fleet.leader() or 0
        shard = pipeline.lease(worker)
        batch_np = synthetic_batch(args.batch, args.seq, cfg.vocab_size,
                                   step=step)
        batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
        params, opt_state, loss = step_fn(params, opt_state, batch)
        if shard:
            pipeline.complete(worker, shard)
        losses.append(float(loss))
        if step % 10 == 0:
            print(f"step {step:4d} loss {losses[-1]:7.4f} "
                  f"({time.time() - t0:6.1f}s)")
        step += 1
    ckpt.save(args.steps, params, opt_state)
    print(f"final loss {losses[-1]:.4f} (first {losses[0]:.4f}); "
          f"loss decreased: {losses[-1] < losses[0]}")
    print(f"checkpoints: "
          f"{plane.client.execute('ls', '/ckpt/e2e').value}")


if __name__ == "__main__":
    main()
