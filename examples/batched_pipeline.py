"""Batched multi-namenode request pipeline in 60 seconds (paper §2.2, §7.2).

Builds a 4-namenode cluster over one partitioned store, materializes a
Spotify-shaped namespace, then replays the same §7.2 trace twice through
the shared-queue pipeline: once sequentially (batch_size=1) and once
batched (batch_size=16). Shows the measured DB round-trip savings from
grouped path validation (batched PK reads + vectorized phash partition
grouping) and verifies the namespace ends up identical.

  PYTHONPATH=src python examples/batched_pipeline.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (MetadataStore, NamenodeCluster, RequestPipeline,
                        format_fs, materialize_namespace, namespace_snapshot)
from repro.core.workload import (NamespaceSpec, SyntheticNamespace,
                                 make_spotify_trace)


def build_cluster(n_namenodes: int):
    store = MetadataStore(n_datanodes=4, replication=2)
    format_fs(store)
    cluster = NamenodeCluster(store, n_namenodes)
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=20, files_per_dir=4)
    n = materialize_namespace(cluster.namenodes[0], ns)
    return store, cluster, ns, n


def main() -> None:
    print("== batched request pipeline ==")
    store_a, cluster_a, ns, n_inodes = build_cluster(4)
    store_b, cluster_b, _, _ = build_cluster(4)
    print(f"materialized namespace: {n_inodes} inodes")

    trace = make_spotify_trace(ns, 600, seed=5)
    print(f"trace: {len(trace)} ops (§7.2 mix, ~67% getBlockLocations)")

    seq = RequestPipeline(cluster_a, batch_size=1).run(trace)
    bat = RequestPipeline(cluster_b, batch_size=16).run(trace)

    print(f"sequential: {seq.total_cost.round_trips} DB round trips "
          f"({seq.ok} ok / {seq.failed} failed)")
    print(f"batched   : {bat.total_cost.round_trips} DB round trips "
          f"({bat.ok} ok / {bat.failed} failed), "
          f"{bat.batched_fraction:.0%} of ops served from batched groups")
    saved = 1 - bat.total_cost.round_trips / seq.total_cost.round_trips
    print(f"round-trip savings: {saved:.1%} "
          "(batched PK validation, one exchange per partition group)")

    per_nn = ", ".join(f"nn{j}={c}" for j, c in sorted(bat.per_nn_ops.items()))
    print(f"ops per namenode: {per_nn}")

    same = namespace_snapshot(store_a) == namespace_snapshot(store_b)
    print(f"namespace identical to sequential execution: {same}")
    assert same


if __name__ == "__main__":
    main()
