"""Metadata-plane scaling demo: the paper's experiment shapes on the DES.

Sweeps namenodes and NDB nodes on the industrial workload, prints the
throughput curve (Fig 8), failover timeline (Fig 11), and the checkpoint-
manifest burst that a 512-chip training job generates.

  PYTHONPATH=src python examples/metadata_scale.py
"""
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core.cluster_sim import HDFSSim, HopsFSSim, profile_ops
from repro.core.workload import (NamespaceSpec, SpotifyWorkload,
                                 SyntheticNamespace)
from repro.metaplane import MetadataPlane


def main() -> None:
    prof = profile_ops()
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=40)

    hd = HDFSSim()
    hd.start_clients(1200, SpotifyWorkload(ns))
    hdfs_tp = hd.run(0.8).throughput
    print(f"HDFS (ANN+SbNN+journal): {hdfs_tp:9,.0f} ops/s")

    for nn, ndb in [(1, 2), (4, 2), (8, 2), (8, 4), (12, 8)]:
        sim = HopsFSSim(n_namenodes=nn, n_ndb=ndb, profiles=prof)
        sim.start_clients(min(2400, 250 * nn), SpotifyWorkload(ns))
        tp = sim.run(0.8).throughput
        print(f"HopsFS {nn:2d} NN / {ndb} NDB:  {tp:9,.0f} ops/s "
              f"({tp / hdfs_tp:4.2f}x HDFS)")

    # failover timeline (Fig 11)
    sim = HopsFSSim(n_namenodes=4, n_ndb=4, profiles=prof)
    sim.start_clients(400, SpotifyWorkload(ns))
    sim.sim.after(1.0, lambda: sim.kill_namenode(0))
    res = sim.run(3.0)
    print("HopsFS failover timeline (NN killed at t=1s):",
          [f"t={s}s:{c}" for s, c in res.timeline])

    # checkpoint-manifest burst: one 512-chip checkpoint commit
    plane = MetadataPlane()
    plane.open_job("nemotron-340b")
    base = plane.begin_checkpoint("nemotron-340b", 1000)
    t0 = time.time()
    n = 2000
    for i in range(n):
        plane.add_shard(base, f"layers/{i % 96}/block/w{i % 8}", i % 512)
    plane.commit_checkpoint("nemotron-340b", 1000)
    dt = time.time() - t0
    print(f"checkpoint manifest: {n} shard rows committed in {dt:.2f}s "
          f"({n / dt:,.0f} rows/s), atomic subtree-rename commit")


if __name__ == "__main__":
    main()
