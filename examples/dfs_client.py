"""DFSClient facade in 60 seconds — the typed operation protocol.

Builds a 3-namenode cluster, then exercises the HDFS-style `DFSClient`:
typed results (`FileStatus`, `BlockLocation`, ...), transparent namenode
failover, deferred batched reads, and the two ops registered purely
through the op registry (`truncate`, `concat`) — plus a brand-new op
registered at runtime with zero dispatch edits.

  PYTHONPATH=src python examples/dfs_client.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (DFSClient, MetadataStore, NamenodeCluster,
                        OpResult, format_fs, register_op)


def main() -> None:
    print("== DFSClient facade ==")
    store = MetadataStore(n_datanodes=4)
    format_fs(store)
    cluster = NamenodeCluster(store, 3)
    dfs = DFSClient(cluster, policy="sticky")

    # -- namespace + block protocol, typed end to end -------------------
    dfs.mkdirs("/warehouse/daily", perm=0o750)
    for part in range(3):
        p = f"/warehouse/daily/part-{part:04d}"
        dfs.create(p, repl=2)
        bid = dfs.add_block(p)
        dfs.complete_block(p, bid, size=128 << 20)
    print("ls:", dfs.ls("/warehouse/daily"))
    st = dfs.stat("/warehouse/daily/part-0000")
    print(f"stat: size={st.size >> 20} MiB repl={st.repl} "
          f"perm={oct(st.perm)}")
    print("open:", dfs.open("/warehouse/daily/part-0000"))

    # -- the registry-registered ops: concat + truncate -----------------
    s = dfs.concat("/warehouse/daily/part-0000",
                   ["/warehouse/daily/part-0001",
                    "/warehouse/daily/part-0002"])
    print(f"concat: {s.blocks_moved} blocks moved, "
          f"size={s.size >> 20} MiB; ls now {dfs.ls('/warehouse/daily')}")
    t = dfs.truncate("/warehouse/daily/part-0000", 200 << 20)
    print(f"truncate: -> {t.size >> 20} MiB "
          f"({t.removed_blocks} block(s) dropped)")

    # -- deferred batch: one pulled batch, grouped PK validation --------
    with dfs.batch() as b:
        h_stat = b.stat("/warehouse/daily/part-0000")
        h_ls = b.ls("/warehouse")
        h_open = b.open("/warehouse/daily/part-0000")
    print("batched:", h_stat.result().size >> 20, "MiB,",
          h_ls.result(), f"{len(h_open.result())} block(s)")

    # -- transparent failover (§7.6.1) ----------------------------------
    cluster.kill(dfs._pick().nn_id)
    st = dfs.stat("/warehouse/daily/part-0000")   # no exception = failover
    print(f"after namenode kill: stat ok (retries={dfs.retries})")

    # -- extensibility proof: a new op, zero dispatch edits -------------
    from repro.core.fs import HopsFSOps

    def file_exists(self, path: str) -> OpResult:
        from repro.core.fs import FileNotFound
        try:
            return OpResult(bool(self.stat(path).value), self.stat(path).cost)
        except FileNotFound:
            from repro.core.store import OpCost
            return OpResult(False, OpCost())

    HopsFSOps.file_exists = file_exists
    register_op("file_exists", "ops", "file_exists", read_only=True)
    print("new op via registry:",
          dfs.call("file_exists", "/warehouse/daily/part-0000").value)


if __name__ == "__main__":
    main()
