"""Quickstart: the paper's system in 60 seconds.

Spins up a HopsFS metadata cluster (3 stateless namenodes over a 4-node
partitioned store), runs file-system ops with Table-3 cost accounting,
executes a subtree operation, survives a namenode failure, and shows the
capacity headline.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (Client, MetadataStore, NamenodeCluster, SubtreeOps,
                        format_fs)
from repro.core.costmodel import capacity_headline, create_depth10_roundtrips


def main() -> None:
    print("== HopsFS quickstart ==")
    store = MetadataStore(n_datanodes=4, replication=2)
    format_fs(store)
    cluster = NamenodeCluster(store, n_namenodes=3)
    client = Client(cluster, policy="round_robin")

    # namespace ops through different namenodes, one shared store
    client.execute("mkdirs", "/user/alice/project")
    for i in range(5):
        client.execute("create", f"/user/alice/project/part-{i:04d}")
    ls = client.execute("ls", "/user/alice/project")
    print(f"ls /user/alice/project -> {ls.value}")
    print(f"   cost: {ls.cost.round_trips} DB round trips "
          f"({ls.cost.ppis} partition-pruned scans)")

    read = client.execute("read", "/user/alice/project/part-0000")
    print(f"read part-0000 -> {read.cost.round_trips} round trips "
          f"(cache hit: depth-independent)")

    # subtree operation (paper §6): batched, isolated, crash-safe
    nn = cluster.alive_namenodes()[0]
    res = SubtreeOps(nn.ops).delete_subtree("/user/alice/project")
    print(f"delete_subtree -> removed {res.value['deleted']} inodes in "
          f"batched parallel transactions")

    # kill a namenode: clients fail over transparently (paper §7.6.1)
    client2 = Client(cluster, policy="sticky", seed=7)
    client2.execute("mkdirs", "/tmp/x")
    cluster.kill(client2._sticky)
    cluster.tick(); cluster.tick(); cluster.tick()
    client2.execute("create", "/tmp/x/after-failover")
    print("namenode killed; client re-selected a live namenode "
          "transparently (no downtime)")

    # headline claims
    ex = create_depth10_roundtrips()
    print(f"inode hint cache: create@depth10 {ex['no_cache']}->"
          f"{ex['cache']} round trips ({ex['improvement_pct']}% saved; "
          f"paper: 58%)")
    cap = capacity_headline()
    print(f"capacity: {cap['ratio']:.0f}x more metadata than HDFS "
          f"(paper: 24x)")


if __name__ == "__main__":
    main()
