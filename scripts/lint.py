"""Source lint over src/ tests/ benchmarks/ examples/ scripts/.

  PYTHONPATH=src python scripts/lint.py      (or: make lint)

Uses **pyflakes** when it is installed.  This container doesn't ship it,
so the default path is a dependency-free fallback that catches the high
signal-to-noise defects:

  * syntax errors (every file must parse);
  * unused imports — an imported name that appears nowhere else in the
    file (module-level ``import x`` / ``from m import x``); ``__init__.py``
    re-export files and names listed in ``__all__`` are exempt;
  * accidental tab indentation (the repo is 4-space).

The fallback intentionally does NOT attempt undefined-name analysis; that
is pyflakes' job when available.
"""
from __future__ import annotations

import ast
import re
import sys
from pathlib import Path
from typing import List

ROOT = Path(__file__).resolve().parents[1]
LINT_DIRS = ("src", "tests", "benchmarks", "examples", "scripts")


def py_files() -> List[Path]:
    out: List[Path] = []
    for d in LINT_DIRS:
        out.extend(sorted((ROOT / d).rglob("*.py")))
    return out


def run_pyflakes(files: List[Path]) -> int:
    from pyflakes.api import checkPath
    from pyflakes.reporter import Reporter
    rep = Reporter(sys.stdout, sys.stderr)
    return sum(checkPath(str(f), rep) for f in files)


def _imported_names(tree: ast.AST) -> List[ast.alias]:
    names = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            names.extend(node.names)
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            names.extend(a for a in node.names if a.name != "*")
    return names


def check_file(path: Path) -> List[str]:
    rel = path.relative_to(ROOT)
    text = path.read_text()
    errors: List[str] = []
    try:
        tree = ast.parse(text, filename=str(path))
    except SyntaxError as e:
        return [f"{rel}:{e.lineno}: syntax error: {e.msg}"]
    for i, line in enumerate(text.splitlines(), 1):
        if line.startswith("\t"):
            errors.append(f"{rel}:{i}: tab indentation")
    if path.name == "__init__.py":      # re-export surface by convention
        return errors
    exported = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == "__all__" \
                        and isinstance(node.value, (ast.List, ast.Tuple)):
                    exported |= {c.value for c in node.value.elts
                                 if isinstance(c, ast.Constant)}
    for alias in _imported_names(tree):
        bound = alias.asname or alias.name.split(".")[0]
        if bound.startswith("_") or bound in exported:
            continue
        # used iff the bound name occurs outside import statements; a
        # word-boundary scan over non-import lines keeps this robust to
        # string annotations without real name-resolution machinery
        pat = re.compile(rf"\b{re.escape(bound)}\b")
        used = False
        for line in text.splitlines():
            stripped = line.lstrip()
            if stripped.startswith(("import ", "from ")):
                continue
            if pat.search(line):
                used = True
                break
        if not used:
            errors.append(f"{rel}: unused import '{bound}'")
    return errors


def main() -> int:
    files = py_files()
    try:
        import pyflakes  # noqa: F401  (probe only)
    except ImportError:
        pass
    else:
        n = run_pyflakes(files)
        print(f"lint: pyflakes over {len(files)} files -> "
              f"{n} finding(s)")
        return 1 if n else 0
    errors: List[str] = []
    for f in files:
        errors.extend(check_file(f))
    if errors:
        print("lint: FAIL")
        for e in errors:
            print("  -", e)
        return 1
    print(f"lint: OK ({len(files)} files, fallback checker — "
          "install pyflakes for full analysis)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
