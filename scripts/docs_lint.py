"""Docs lint: the documentation suite exists, is substantive, every repo
path it references resolves, and every backtick-quoted ``module.symbol``
code reference resolves via import/getattr — so docs cannot silently rot
when code moves or is renamed.

  PYTHONPATH=src python scripts/docs_lint.py      (or: make docs-lint)

Checks:
  * README.md, docs/ARCHITECTURE.md, docs/API.md, docs/BENCHMARKS.md and
    docs/HINTS.md exist and are non-trivial;
  * every `path`-looking backtick reference into src/ tests/ benchmarks/
    examples/ docs/ scripts/ points at a real file or directory;
  * every dotted backtick reference anchored in this repo's code — a
    module (`workload.lower_trace`, `cluster_sim.SimParams`), a
    `repro.core` export (`Namenode.execute_batch`, `OpSpec.lease_order`),
    or a symbol of any `repro.core` submodule (`BatchedHopsFSSim`) —
    resolves to a live object. Dotted tokens anchored NOWHERE in the repo
    (example variables like `dfs.batch`, version numbers) are prose, not
    code references, and are skipped;
  * the top-level keys documented in docs/BENCHMARKS.md's "Output schema"
    block match the actual top-level keys of BENCH_throughput.json, both
    directions — the benchmark artifact and its documentation cannot
    drift apart silently;
  * the artifact's `failover` section (§7.6 kill-a-namenode-mid-replay
    measurement) carries the full metric set the chaos suite and docs
    rely on (dip depth, recovery time/ops, zero-bin count, fault events);
  * the artifact's `elasticity` section (scale-the-fleet-mid-replay
    measurement, docs/ELASTICITY.md) carries the full metric set the
    elastic-pool suite and docs rely on (scale-out gain, zero-bin count,
    hint hit rates around migration, oracle equality, scale events);
  * the artifact's `overload` section (gray-failure goodput measurement,
    docs/ROBUSTNESS.md) carries the full metric set the admission suite
    and docs rely on (protected vs unprotected goodput and p99, breaker
    trips, admission telemetry, oracle equality) and its headline
    acceptance criteria hold: zero protected late completions, protected
    goodput strictly above unprotected;
  * the artifact's `columnar` section (struct-of-arrays engine
    differential bench, docs/ARCHITECTURE.md's columnar-engine section)
    carries the full metric set, shows the oracle lock holding
    (`state_matches_oracle` true) and genuinely fused kernel launches
    (launch count strictly below op count);
  * the artifact's `big_dir` section (§6 subtree protocol at 10^5-inode
    scale, docs/ARCHITECTURE.md's million-entry-directories section)
    carries the full metric set and its acceptance criteria hold:
    adjacent-op p99 within 3x of the no-subtree baseline, dict/columnar
    and incremental/legacy state equality, treeagg launches with zero
    fallback demotions, and a genuinely paced delete.
"""
from __future__ import annotations

import dataclasses
import importlib
import json
import pkgutil
import re
import sys
import types
from pathlib import Path
from typing import Dict, Optional

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT))            # benchmarks/, scripts/
sys.path.insert(0, str(ROOT / "src"))    # repro

DOCS = ["README.md", "docs/ARCHITECTURE.md", "docs/API.md",
        "docs/BENCHMARKS.md", "docs/CHAOS.md", "docs/ELASTICITY.md",
        "docs/HINTS.md", "docs/ROBUSTNESS.md"]
MIN_BYTES = 1500
REF_PREFIXES = ("src/", "tests/", "benchmarks/", "examples/", "docs/",
                "scripts/")
#: module-name prefixes tried in front of a dotted token; the bare base
#: only applies to the repo's own top-level packages (REPO_ROOTS) — a
#: stdlib/site-packages module must never anchor a doc token, else prose
#: like `pytest.something` would fail the lint and a dangling `re.py`
#: would pass it
IMPORT_BASES = ("", "repro.", "repro.core.", "repro.kernels.",
                "benchmarks.")
REPO_ROOTS = ("repro", "benchmarks", "scripts", "examples", "tests")

# `...`-quoted tokens that look like repo paths or dotted symbols
_REF = re.compile(r"`([A-Za-z0-9_./-]+)`")

_symbol_cache: Dict[str, Optional[bool]] = {}


def _import(name: str):
    try:
        return importlib.import_module(name)
    except Exception:
        return None


def _walk_attrs(obj, parts) -> bool:
    for i, attr in enumerate(parts):
        try:
            obj = getattr(obj, attr)
        except AttributeError:
            # dataclass fields without defaults are not class attributes —
            # accept one only as the FINAL part (nothing to walk past it)
            return (i == len(parts) - 1
                    and dataclasses.is_dataclass(obj)
                    and any(f.name == attr
                            for f in dataclasses.fields(obj)))
    return True


def _submodule_roots():
    """name -> object for every public symbol of every repro.core
    submodule (anchors refs like `BatchedHopsFSSim.batched_ops` that are
    not re-exported from the package root). Imported FOREIGN modules
    (``import time`` inside a submodule) are excluded — the stdlib must
    not anchor doc tokens."""
    roots: Dict[str, object] = {}

    def repo_owned(obj) -> bool:
        if isinstance(obj, types.ModuleType):
            return getattr(obj, "__name__", "").startswith(
                ("repro", "benchmarks"))
        return True

    core = _import("repro.core")
    if core is None:
        return roots
    for info in pkgutil.iter_modules(core.__path__):
        mod = _import(f"repro.core.{info.name}")
        if mod is None:
            continue
        for name in dir(mod):
            obj = getattr(mod, name)
            if not name.startswith("__") and repo_owned(obj):
                roots.setdefault(name, obj)
    for name in dir(core):
        obj = getattr(core, name)
        if not name.startswith("__") and repo_owned(obj):
            roots[name] = obj
    return roots


_ATTR_ROOTS = None


def symbol_status(tok: str) -> Optional[bool]:
    """True = resolves, False = anchored in repo code but dangling,
    None = not a code reference (skip)."""
    global _ATTR_ROOTS
    if tok in _symbol_cache:
        return _symbol_cache[tok]
    parts = tok.split(".")
    first = parts[0]
    anchored = resolved = False
    for base in IMPORT_BASES:
        if base == "" and first not in REPO_ROOTS:
            continue
        if _import(base + first) is None:
            continue
        anchored = True
        # longest importable module prefix, then getattr the rest
        for k in range(len(parts), 0, -1):
            mod = _import(base + ".".join(parts[:k]))
            if mod is None:
                continue
            if _walk_attrs(mod, parts[k:]):
                resolved = True
            break
        if resolved:
            break
    if not resolved:
        if _ATTR_ROOTS is None:
            _ATTR_ROOTS = _submodule_roots()
        if first in _ATTR_ROOTS:
            anchored = True
            resolved = _walk_attrs(_ATTR_ROOTS[first], parts[1:])
    status = True if resolved else (False if anchored else None)
    _symbol_cache[tok] = status
    return status


def check_doc(path: Path) -> list:
    errors = []
    if not path.exists():
        return [f"{path.relative_to(ROOT)}: missing"]
    text = path.read_text()
    if len(text) < MIN_BYTES:
        errors.append(f"{path.relative_to(ROOT)}: suspiciously short "
                      f"({len(text)} bytes < {MIN_BYTES})")
    for tok in _REF.findall(text):
        if "/" in tok or tok.startswith(REF_PREFIXES):
            if not tok.startswith(REF_PREFIXES):
                continue
            target = ROOT / tok
            # allow references to glob-ish groups like src/repro/kernels/
            if target.exists():
                continue
            # `a/{b,c}/d` brace groups: every expansion must exist
            m = re.match(r"(.*)\{([^}]+)\}(.*)", tok)
            if m and all((ROOT / (m.group(1) + part + m.group(3))).exists()
                         for part in m.group(2).split(",")):
                continue
            errors.append(f"{path.relative_to(ROOT)}: dangling reference "
                          f"`{tok}`")
            continue
        if tok.endswith(".py"):
            # bare module filename (`ops_registry.py`): the file must
            # exist somewhere under the repo's code trees (repo-anchored
            # imports only — the stdlib must not vouch for `re.py`)
            stem = tok[:-3]
            if any(_import(base + stem) for base in IMPORT_BASES
                   if base != "" or stem in REPO_ROOTS) \
                    or list(ROOT.glob(f"*/{tok}")) \
                    or list(ROOT.glob(f"*/**/{tok}")):
                continue
            errors.append(f"{path.relative_to(ROOT)}: dangling module "
                          f"reference `{tok}`")
            continue
        if "." in tok and not tok[0].isdigit():
            if symbol_status(tok) is False:
                errors.append(f"{path.relative_to(ROOT)}: dangling code "
                              f"reference `{tok}` (anchored in repo code "
                              f"but does not resolve via import/getattr)")
    return errors


#: top-level key lines of the jsonc schema block: exactly two spaces of
#: indent, a quoted identifier, a colon
_SCHEMA_KEY = re.compile(r'^  "([A-Za-z_][A-Za-z0-9_]*)":', re.M)


def check_benchmarks_schema(doc: Path, artifact: Path) -> list:
    """Cross-check the documented `BENCH_throughput.json` top-level schema
    against the committed artifact: every documented key must exist in the
    artifact, and every artifact key must be documented."""
    if not doc.exists():
        return []                      # the missing doc is reported above
    if not artifact.exists():
        return [f"{artifact.name}: missing (docs/BENCHMARKS.md documents "
                f"its schema; regenerate with `make bench`)"]
    text = doc.read_text()
    m = re.search(r"```jsonc\n(.*?)```", text, re.S)
    if m is None:
        return [f"{doc.relative_to(ROOT)}: no ```jsonc schema block to "
                f"cross-check against {artifact.name}"]
    documented = set(_SCHEMA_KEY.findall(m.group(1)))
    try:
        actual = set(json.loads(artifact.read_text()))
    except Exception as e:
        return [f"{artifact.name}: unparseable ({e})"]
    errors = []
    for k in sorted(documented - actual):
        errors.append(f"{doc.relative_to(ROOT)}: documents top-level key "
                      f"`{k}` absent from {artifact.name}")
    for k in sorted(actual - documented):
        errors.append(f"{artifact.name}: top-level key `{k}` undocumented "
                      f"in {doc.relative_to(ROOT)}'s schema block")
    return errors


#: metric keys the `failover` section of BENCH_throughput.json must carry
#: (consumed by docs/CHAOS.md and the chaos suite's bench cross-checks)
FAILOVER_KEYS = frozenset({
    "n_namenodes", "killed_namenode", "kill_at_s", "restart_at_s",
    "horizon_s", "timeline_bin_s", "steady_ops_per_bin",
    "dip_ops_per_bin", "dip_depth_pct", "recovered", "recovery_s",
    "ops_to_recovery", "zero_bins_after_kill", "requeued_ops",
    "completed_ops", "fault_events",
})


def check_failover_schema(artifact: Path) -> list:
    """The bench artifact's §7.6 failover section must exist and carry
    every documented metric key."""
    if not artifact.exists():
        return []                 # already reported by the schema check
    try:
        report = json.loads(artifact.read_text())
    except Exception:
        return []                 # already reported by the schema check
    fo = report.get("failover")
    if not isinstance(fo, dict):
        return [f"{artifact.name}: no `failover` section (regenerate "
                f"with `make bench`)"]
    errors = []
    for k in sorted(FAILOVER_KEYS - set(fo)):
        errors.append(f"{artifact.name}: failover section missing "
                      f"metric `{k}`")
    ev = fo.get("fault_events")
    if not ev:
        errors.append(f"{artifact.name}: failover section recorded no "
                      f"fault events — no namenode was killed")
    return errors


#: metric keys the `elasticity` section of BENCH_throughput.json must
#: carry (consumed by docs/ELASTICITY.md and the elastic-pool suite)
ELASTICITY_KEYS = frozenset({
    "n_namenodes_base", "n_namenodes_peak", "scale_out_at_s",
    "scale_in_at_s", "horizon_s", "timeline_bin_s", "steady_ops_per_bin",
    "scaled_ops_per_bin", "scale_out_gain_pct",
    "zero_bins_during_scale_out", "scale_in_recovered",
    "scale_in_recovery_s", "completed_ops", "scale_events",
    "hint_hit_rate_before", "hint_hit_rate_after",
    "hint_hit_rate_drop_pct", "migrated_hint_entries",
    "pool_scale_outs", "pool_scale_ins", "state_matches_sequential",
})


def check_elasticity_schema(artifact: Path) -> list:
    """The bench artifact's elastic-pool section must exist and carry
    every documented metric key."""
    if not artifact.exists():
        return []                 # already reported by the schema check
    try:
        report = json.loads(artifact.read_text())
    except Exception:
        return []                 # already reported by the schema check
    el = report.get("elasticity")
    if not isinstance(el, dict):
        return [f"{artifact.name}: no `elasticity` section (regenerate "
                f"with `make bench`)"]
    errors = []
    for k in sorted(ELASTICITY_KEYS - set(el)):
        errors.append(f"{artifact.name}: elasticity section missing "
                      f"metric `{k}`")
    ev = el.get("scale_events")
    if not ev:
        errors.append(f"{artifact.name}: elasticity section recorded no "
                      f"scale events — the fleet never resized")
    return errors


#: metric keys the `overload` section of BENCH_throughput.json must
#: carry (consumed by docs/ROBUSTNESS.md and the admission suite)
OVERLOAD_KEYS = frozenset({
    "n_namenodes", "slow_namenode", "delay_ticks_per_exchange", "n_ops",
    "n_tenants", "zipf_s", "batch_size", "deadline_budget_ticks",
    "deadline_per_op_ticks", "unprotected", "protected",
    "goodput_gain_pct", "planner_deadline_shed",
    "planner_breaker_rerouted", "breaker_trips", "breaker_open_at_end",
    "admission", "recovery_redriven_ops", "state_matches_sequential",
})

#: per-run metric keys of the `unprotected` / `protected` sub-sections
OVERLOAD_RUN_KEYS = frozenset({
    "ok", "goodput_ops", "goodput_frac", "late_completions",
    "failed_by_error", "per_tenant_p99_ticks", "worst_tenant_p99_ticks",
    "clock_advance_ticks",
})


def check_overload_schema(artifact: Path) -> list:
    """The bench artifact's gray-failure overload section must exist,
    carry every documented metric key, and satisfy the acceptance
    criteria the robustness layer is sold on."""
    if not artifact.exists():
        return []                 # already reported by the schema check
    try:
        report = json.loads(artifact.read_text())
    except Exception:
        return []                 # already reported by the schema check
    ov = report.get("overload")
    if not isinstance(ov, dict):
        return [f"{artifact.name}: no `overload` section (regenerate "
                f"with `make bench`)"]
    errors = []
    for k in sorted(OVERLOAD_KEYS - set(ov)):
        errors.append(f"{artifact.name}: overload section missing "
                      f"metric `{k}`")
    for run in ("unprotected", "protected"):
        sub = ov.get(run)
        if not isinstance(sub, dict):
            continue              # missing-key error already emitted
        for k in sorted(OVERLOAD_RUN_KEYS - set(sub)):
            errors.append(f"{artifact.name}: overload.{run} missing "
                          f"metric `{k}`")
    u, p = ov.get("unprotected"), ov.get("protected")
    if isinstance(u, dict) and isinstance(p, dict):
        if p.get("late_completions") != 0:
            errors.append(f"{artifact.name}: overload.protected completed "
                          f"{p.get('late_completions')} ops past their "
                          f"deadline — deadline shedding is not airtight")
        if not (p.get("goodput_frac", 0) > u.get("goodput_frac", 1)):
            errors.append(f"{artifact.name}: overload protection did not "
                          f"beat the unprotected run on goodput "
                          f"({p.get('goodput_frac')} <= "
                          f"{u.get('goodput_frac')})")
    if not ov.get("breaker_trips"):
        errors.append(f"{artifact.name}: overload section recorded no "
                      f"breaker trips — the slow namenode was never "
                      f"quarantined")
    if ov.get("state_matches_sequential") is not True:
        errors.append(f"{artifact.name}: overload recovery did not "
                      f"converge on the sequential oracle's namespace")
    return errors


#: metric keys the `columnar` section of BENCH_throughput.json must carry
#: (consumed by docs/ARCHITECTURE.md's columnar-engine section and the
#: differential suite in tests/test_columnar_store.py)
COLUMNAR_KEYS = frozenset({
    "batch_size", "window", "n_namenodes", "ops", "modes",
    "hintchain_launches", "pkval_launches", "pkval_probes",
    "pkval_demotions", "fused_launches", "launches_per_op",
    "wall_s_dict", "wall_s_columnar", "state_matches_oracle",
})

#: per-mode metric keys of the `modes.spotify` / `modes.write_heavy`
#: sub-sections
COLUMNAR_MODE_KEYS = frozenset({
    "ops", "ok", "failed", "windows", "hintchain_launches",
    "pkval_launches", "pkval_probes", "pkval_demotions",
    "window_ms_dict", "window_ms_columnar", "state_matches_oracle",
})


def check_columnar_schema(artifact: Path) -> list:
    """The bench artifact's columnar-engine section must exist, carry
    every documented metric key, and satisfy the oracle lock the engine
    is sold on: byte-identical final state and FUSED kernel launches
    (launch count orders of magnitude below op count)."""
    if not artifact.exists():
        return []                 # already reported by the schema check
    try:
        report = json.loads(artifact.read_text())
    except Exception:
        return []                 # already reported by the schema check
    co = report.get("columnar")
    if not isinstance(co, dict):
        return [f"{artifact.name}: no `columnar` section (regenerate "
                f"with `make bench`)"]
    errors = []
    for k in sorted(COLUMNAR_KEYS - set(co)):
        errors.append(f"{artifact.name}: columnar section missing "
                      f"metric `{k}`")
    for mode, sub in (co.get("modes") or {}).items():
        if not isinstance(sub, dict):
            errors.append(f"{artifact.name}: columnar.modes.{mode} is "
                          f"not a metrics object")
            continue
        for k in sorted(COLUMNAR_MODE_KEYS - set(sub)):
            errors.append(f"{artifact.name}: columnar.modes.{mode} "
                          f"missing metric `{k}`")
    if co.get("state_matches_oracle") is not True:
        errors.append(f"{artifact.name}: columnar replay diverged from "
                      f"the dict-store oracle (state_matches_oracle "
                      f"!= true)")
    if not co.get("fused_launches"):
        errors.append(f"{artifact.name}: columnar section recorded no "
                      f"fused kernel launches — the gates never opened")
    elif not co.get("fused_launches", 0) < co.get("ops", 0):
        errors.append(f"{artifact.name}: columnar launches "
                      f"({co.get('fused_launches')}) not below op count "
                      f"({co.get('ops')}) — batching is not fused")
    return errors


#: metric keys the `big_dir` section of BENCH_throughput.json must carry
#: (consumed by docs/ARCHITECTURE.md's million-entry-directories section
#: and the subtree suites in tests/test_subtree_properties.py /
#: tests/test_subtree_scaling.py)
BIG_DIR_KEYS = frozenset({
    "n_children", "total_inodes", "batch_size", "deleted", "chunks",
    "waves", "peak_frontier", "subtree_wall_s_dict",
    "subtree_wall_s_columnar", "adjacent_ops", "pace_invocations",
    "baseline_p50_ms", "baseline_p99_ms", "paced_p50_ms", "paced_p99_ms",
    "p99_ratio", "treeagg_launches", "treeagg_demotions",
    "state_matches_oracle", "incremental_matches_legacy",
})

#: adjacent-op p99 while the paced delete runs may be at most this
#: multiple of the no-subtree baseline (the "namespace stays live" bar)
BIG_DIR_MAX_P99_RATIO = 3.0


def check_big_dir_schema(artifact: Path) -> list:
    """The bench artifact's big-directory section must exist, carry
    every documented metric key, and satisfy the §6-at-scale acceptance
    criteria: adjacent-op p99 within 3x of the no-subtree baseline,
    both equality flags true, and the treeagg kernel gate genuinely
    opened (launches >= 1 with zero fallback demotions)."""
    if not artifact.exists():
        return []                 # already reported by the schema check
    try:
        report = json.loads(artifact.read_text())
    except Exception:
        return []                 # already reported by the schema check
    bd = report.get("big_dir")
    if not isinstance(bd, dict):
        return [f"{artifact.name}: no `big_dir` section (regenerate "
                f"with `make bench`)"]
    errors = []
    for k in sorted(BIG_DIR_KEYS - set(bd)):
        errors.append(f"{artifact.name}: big_dir section missing "
                      f"metric `{k}`")
    ratio = bd.get("p99_ratio")
    if isinstance(ratio, (int, float)) \
            and ratio > BIG_DIR_MAX_P99_RATIO:
        errors.append(f"{artifact.name}: adjacent-op p99 degraded "
                      f"{ratio}x during the paced delete (bar: "
                      f"{BIG_DIR_MAX_P99_RATIO}x over the no-subtree "
                      f"baseline)")
    if bd.get("state_matches_oracle") is not True:
        errors.append(f"{artifact.name}: big_dir replay diverged "
                      f"between the dict and columnar backends "
                      f"(state_matches_oracle != true)")
    if bd.get("incremental_matches_legacy") is not True:
        errors.append(f"{artifact.name}: incremental subtree engine "
                      f"diverged from the legacy engine "
                      f"(incremental_matches_legacy != true)")
    if not bd.get("treeagg_launches"):
        errors.append(f"{artifact.name}: big_dir section recorded no "
                      f"treeagg launches — the kernel gate never opened")
    if bd.get("treeagg_demotions"):
        errors.append(f"{artifact.name}: big_dir run demoted "
                      f"{bd.get('treeagg_demotions')} treeagg launches "
                      f"to the fallback — the kernel is not healthy")
    if not bd.get("pace_invocations"):
        errors.append(f"{artifact.name}: big_dir delete never paced — "
                      f"no adjacent ops interleaved between chunks")
    return errors


def main() -> int:
    errors = []
    for rel in DOCS:
        errors.extend(check_doc(ROOT / rel))
    errors.extend(check_benchmarks_schema(ROOT / "docs/BENCHMARKS.md",
                                          ROOT / "BENCH_throughput.json"))
    errors.extend(check_failover_schema(ROOT / "BENCH_throughput.json"))
    errors.extend(check_elasticity_schema(ROOT / "BENCH_throughput.json"))
    errors.extend(check_overload_schema(ROOT / "BENCH_throughput.json"))
    errors.extend(check_columnar_schema(ROOT / "BENCH_throughput.json"))
    errors.extend(check_big_dir_schema(ROOT / "BENCH_throughput.json"))
    if errors:
        print("docs-lint: FAIL")
        for e in errors:
            print("  -", e)
        return 1
    print(f"docs-lint: OK ({len(DOCS)} docs checked, "
          f"{len(_symbol_cache)} code references resolved)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
