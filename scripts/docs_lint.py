"""Docs lint: the documentation suite exists, is substantive, and every
repo path it references actually resolves.

  PYTHONPATH=src python scripts/docs_lint.py      (or: make docs-lint)

Checks:
  * README.md, docs/ARCHITECTURE.md, docs/BENCHMARKS.md exist and are
    non-trivial;
  * every `path`-looking backtick reference into src/ tests/ benchmarks/
    examples/ docs/ scripts/ points at a real file or directory;
  * commands the docs tell users to run reference real module files.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DOCS = ["README.md", "docs/ARCHITECTURE.md", "docs/API.md",
        "docs/BENCHMARKS.md"]
MIN_BYTES = 1500
REF_PREFIXES = ("src/", "tests/", "benchmarks/", "examples/", "docs/",
                "scripts/")

# `...`-quoted tokens that look like repo paths
_REF = re.compile(r"`([A-Za-z0-9_./-]+)`")


def check_doc(path: Path) -> list:
    errors = []
    if not path.exists():
        return [f"{path.relative_to(ROOT)}: missing"]
    text = path.read_text()
    if len(text) < MIN_BYTES:
        errors.append(f"{path.relative_to(ROOT)}: suspiciously short "
                      f"({len(text)} bytes < {MIN_BYTES})")
    for tok in _REF.findall(text):
        if not tok.startswith(REF_PREFIXES):
            continue
        target = ROOT / tok
        # allow references to glob-ish groups like src/repro/kernels/
        if target.exists():
            continue
        # `a/{b,c}/d` brace groups: every expansion must exist
        m = re.match(r"(.*)\{([^}]+)\}(.*)", tok)
        if m and all((ROOT / (m.group(1) + part + m.group(3))).exists()
                     for part in m.group(2).split(",")):
            continue
        errors.append(f"{path.relative_to(ROOT)}: dangling reference "
                      f"`{tok}`")
    return errors


def main() -> int:
    errors = []
    for rel in DOCS:
        errors.extend(check_doc(ROOT / rel))
    if errors:
        print("docs-lint: FAIL")
        for e in errors:
            print("  -", e)
        return 1
    print(f"docs-lint: OK ({len(DOCS)} docs checked)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
