# Tier-1 tests, lint, example smoke, benchmarks, and docs checks.
PY        ?= python
PYTHONPATH := src

.PHONY: test pytest chaos elastic overload columnar bigdir lint smoke bench bench-all bench-quick docs-lint

test: lint smoke           ## default flow: lint + example smoke + tier-1 suite
	PYTHONPATH=$(PYTHONPATH) $(PY) -m pytest -x -q

pytest:                  ## tier-1 suite only (ROADMAP verify command)
	PYTHONPATH=$(PYTHONPATH) $(PY) -m pytest -x -q

chaos:                   ## fault-injection / failover recovery suite (docs/CHAOS.md)
	PYTHONPATH=$(PYTHONPATH) $(PY) -m pytest tests/test_chaos_recovery.py -q -m chaos

elastic:                 ## elastic namenode pool suite (docs/ELASTICITY.md)
	PYTHONPATH=$(PYTHONPATH) $(PY) -m pytest tests/test_elastic_pool.py -q

overload:                ## overload-hardened request path suite (docs/ROBUSTNESS.md)
	PYTHONPATH=$(PYTHONPATH) $(PY) -m pytest tests/test_admission.py -q

columnar:                ## columnar engine differential + kernel suites (docs/ARCHITECTURE.md)
	PYTHONPATH=$(PYTHONPATH) $(PY) -m pytest tests/test_columnar_store.py tests/test_columnar_kernels.py tests/test_columnar_properties.py tests/test_scan_scaling.py -q

bigdir:                  ## incremental subtree protocol suites + quick big_dir bench
	PYTHONPATH=$(PYTHONPATH) $(PY) -m pytest tests/test_subtree.py tests/test_subtree_properties.py tests/test_subtree_scaling.py -q
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.trace_replay --quick --only big_dir --out /tmp/bigdir_bench.json

lint:                    ## pyflakes if installed, else the AST fallback
	PYTHONPATH=$(PYTHONPATH) $(PY) scripts/lint.py

smoke:                   ## run the fast examples headless
	PYTHONPATH=$(PYTHONPATH) $(PY) examples/quickstart.py
	PYTHONPATH=$(PYTHONPATH) $(PY) examples/dfs_client.py
	PYTHONPATH=$(PYTHONPATH) $(PY) examples/batched_pipeline.py
	PYTHONPATH=$(PYTHONPATH) $(PY) examples/write_path.py

bench:                   ## Fig 7-style trace replay -> BENCH_throughput.json
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.trace_replay

bench-quick:             ## fast smoke of the trace replay
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.trace_replay --quick

bench-all:               ## every paper figure/table reproduction (CSV)
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.run --quick

docs-lint:               ## docs exist + their repo-path references resolve
	PYTHONPATH=$(PYTHONPATH) $(PY) scripts/docs_lint.py
