# Tier-1 tests, benchmarks, and docs checks — one invocation each.
PY        ?= python
PYTHONPATH := src

.PHONY: test bench bench-all bench-quick docs-lint

test:                    ## tier-1 suite (ROADMAP verify command)
	PYTHONPATH=$(PYTHONPATH) $(PY) -m pytest -x -q

bench:                   ## Fig 7-style trace replay -> BENCH_throughput.json
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.trace_replay

bench-quick:             ## fast smoke of the trace replay
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.trace_replay --quick

bench-all:               ## every paper figure/table reproduction (CSV)
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.run --quick

docs-lint:               ## docs exist + their repo-path references resolve
	PYTHONPATH=$(PYTHONPATH) $(PY) scripts/docs_lint.py
