"""Recompute the ANALYTIC fields (kernelized memory, TPU collective model,
roofline terms) of every results/dryrun JSON from the stored measured data
— no recompilation. Used when the analytic models are refined.

  PYTHONPATH=src python benchmarks/rederive.py
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

from repro.configs import get_config                      # noqa: E402
from repro.launch.analytic import (analytic_bytes,        # noqa: E402
                                   analytic_collective_bytes)
from repro.launch.dryrun import HBM_BW, ICI_BW, PEAK_FLOPS  # noqa: E402
from repro.parallel.sharding import MeshPolicy            # noqa: E402

RESULTS = ROOT / "results" / "dryrun"


def mesh_shape_of(c):
    dims = [int(x) for x in c["mesh"].split("x")]
    names = ("pod", "data", "model") if len(dims) == 3 else ("data", "model")
    return dict(zip(names, dims))


def rederive(path: Path) -> bool:
    try:
        c = json.loads(path.read_text())
    except json.JSONDecodeError:      # concurrent writer: skip this pass
        return False
    if "per_device" not in c:
        return False
    parts = path.stem.split("__")
    arch, shape = parts[0], parts[1]
    variant = parts[3] if len(parts) > 3 else None
    cfg = get_config(arch)
    if variant == "grad_compress":
        cfg = cfg.derive(grad_compress=True)
    if variant == "capacity_1x":
        cfg = cfg.derive(capacity_factor=1.0)
    ms = mesh_shape_of(c)
    pol = MeshPolicy(fsdp=c["policy"]["fsdp"],
                     seq_shard=c["policy"]["seq_shard"],
                     rules=tuple(c["policy"]["rules"].items()))
    ana = analytic_bytes(cfg, shape, pol, ms)
    ana_coll = analytic_collective_bytes(cfg, shape, pol, ms)
    pd = c["per_device"]
    pd["bytes_kernelized"] = ana["total"]
    pd["bytes_breakdown"] = ana
    pd["collective_bytes_analytic"] = ana_coll["total"]
    pd["collective_breakdown"] = ana_coll
    compute_s = pd["flops"] / PEAK_FLOPS
    memory_s = ana["total"] / HBM_BW
    collective_s = ana_coll["total"] / ICI_BW
    dom = max((("compute", compute_s), ("memory", memory_s),
               ("collective", collective_s)), key=lambda t: t[1])
    c["roofline"].update({
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dom[0],
        "bound_s": dom[1]})
    mf = c["model_flops"]
    n_chips = c["n_chips"]
    mf["roofline_fraction"] = ((mf["model_flops"] / n_chips / PEAK_FLOPS)
                               / dom[1] if dom[1] else 0.0)
    path.write_text(json.dumps(c, indent=1))
    return True


def main() -> None:
    n = 0
    for p in sorted(RESULTS.glob("*.json")):
        if rederive(p):
            n += 1
    print(f"rederived {n} cell files")


if __name__ == "__main__":
    main()
