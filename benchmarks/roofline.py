"""§Roofline table: reads results/dryrun/*.json (written by
repro.launch.dryrun) and emits the per-(arch x shape) three-term analysis.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Tuple

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"

Row = Tuple[str, float, str]


def load_cells(mesh: str = "16x16") -> List[Dict]:
    out = []
    for p in sorted(RESULTS.glob(f"*__{mesh}.json")):
        out.append(json.loads(p.read_text()))
    return out


def bench_roofline(quick=False) -> List[Row]:
    rows: List[Row] = []
    cells = load_cells()
    if not cells:
        return [("roofline.missing", 0.0,
                 "run: python -m repro.launch.dryrun --all")]
    for c in cells:
        rl = c.get("roofline")
        if not rl:
            continue
        mf = c["model_flops"]
        rows.append((
            f"roofline.{c['arch']}.{c['shape']}", 0.0,
            f"C={rl['compute_s']:.3f}s M={rl['memory_s']:.3f}s "
            f"X={rl['collective_s']:.3f}s dom={rl['dominant']} "
            f"frac={mf['roofline_fraction']:.3f} "
            f"useful={mf['useful_ratio']:.2f}"))
    n_ok = sum(1 for c in cells if c.get("compile_ok"))
    rows.append(("roofline.compiled_cells", 0.0,
                 f"{n_ok}/{len(cells)} single-pod cells compiled"))
    multi = load_cells("2x16x16")
    n_mp = sum(1 for c in multi if c.get("compile_ok"))
    rows.append(("roofline.multipod_cells", 0.0,
                 f"{n_mp} multi-pod (2x16x16) cells compiled"))
    return rows


def table_markdown(mesh: str = "16x16") -> str:
    """EXPERIMENTS.md §Roofline table."""
    cells = load_cells(mesh)
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | MODEL/HLO | roofline frac | bytes/dev (GB) |",
             "|---|---|---|---|---|---|---|---|---|"]
    for c in cells:
        rl = c.get("roofline")
        if not rl:
            continue
        mf = c["model_flops"]
        mem_gb = (c["memory"]["argument_bytes_per_device"] +
                  c["memory"]["temp_bytes_per_device"]) / 1e9
        lines.append(
            f"| {c['arch']} | {c['shape']} | {rl['compute_s']:.3f} | "
            f"{rl['memory_s']:.3f} | {rl['collective_s']:.3f} | "
            f"**{rl['dominant']}** | {mf['useful_ratio']:.2f} | "
            f"{mf['roofline_fraction']:.3f} | {mem_gb:.1f} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(table_markdown())
