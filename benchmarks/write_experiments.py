"""Regenerate the generated sections of EXPERIMENTS.md from
results/dryrun/*.json (dry-run summary, roofline table, observations,
perf-variant diffs).

  PYTHONPATH=src python benchmarks/write_experiments.py
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))
sys.path.insert(0, str(ROOT))

from benchmarks.roofline import load_cells, table_markdown   # noqa: E402
from repro.configs import cells

RESULTS = ROOT / "results" / "dryrun"


def dryrun_summary() -> str:
    single = {c["arch"] + "|" + c["shape"]: c for c in load_cells("16x16")}
    multi = {c["arch"] + "|" + c["shape"]: c for c in load_cells("2x16x16")}
    lines = ["| arch | shape | 16x16 (256 chips) | 2x16x16 (512 chips) | "
             "args GB/dev | temp GB/dev |", "|---|---|---|---|---|---|"]
    n_ok = n_mp = n_skip = 0
    for a, s, skip in cells(include_skipped=True):
        key = f"{a}|{s}"
        if skip:
            lines.append(f"| {a} | {s} | SKIP (full attention @500k; "
                         "DESIGN.md §3.3) | SKIP | — | — |")
            n_skip += 1
            continue
        c1, c2 = single.get(key), multi.get(key)
        ok1 = "✅" if c1 and c1.get("compile_ok") else "❌"
        ok2 = "✅" if c2 and c2.get("compile_ok") else "❌"
        n_ok += bool(c1 and c1.get("compile_ok"))
        n_mp += bool(c2 and c2.get("compile_ok"))
        mem = c1["memory"] if c1 else None
        arg = f"{mem['argument_bytes_per_device'] / 1e9:.2f}" if mem else "—"
        tmp = f"{mem['temp_bytes_per_device'] / 1e9:.2f}" if mem else "—"
        lines.append(f"| {a} | {s} | {ok1} | {ok2} | {arg} | {tmp} |")
    lines.append("")
    lines.append(f"**{n_ok}/34 single-pod cells, {n_mp}/34 multi-pod cells "
                 f"compiled; {n_skip}/6 long_500k cells skipped by design "
                 "(40 assigned cells total).**")
    return "\n".join(lines)


def observations() -> str:
    cs = load_cells("16x16")
    if not cs:
        return "(pending)"
    doms = {}
    best = None
    for c in cs:
        rl = c.get("roofline")
        if not rl:
            continue
        doms[rl["dominant"]] = doms.get(rl["dominant"], 0) + 1
        f = c["model_flops"]["roofline_fraction"]
        if c["kind"] == "train" and (best is None or f > best[1]):
            best = (f"{c['arch']}/{c['shape']}", f)
    out = [f"- dominant-term census: {doms} — the mesh is collective-bound "
           "for most cells at 16-way TP; compute-bound only for the "
           "largest dense matmuls (nemotron/command-r prefill+train).",
           f"- best train roofline fraction: {best[0]} at {best[1]:.2f} — "
           "big dense models amortize collectives best.",
           "- decode cells: absolute per-step terms are milliseconds; "
           "FSDP param-gathers dominate unless weights are replicated "
           "over `data` (see §Perf serve_replicated).",
           "- qwen1_5 (20 heads) and kv<16 GQA archs pay a replicated-"
           "attention tax on the 16-way model axis (DESIGN.md §hardware)."]
    return "\n".join(out)


def perf_log() -> str:
    rows = []
    for p in sorted(RESULTS.glob("*__16x16__*.json")):
        v = json.loads(p.read_text())
        arch, shape, _, variant = p.stem.split("__")
        base_p = RESULTS / f"{arch}__{shape}__16x16.json"
        if not base_p.exists():
            continue
        b = json.loads(base_p.read_text())
        br, vr = b["roofline"], v["roofline"]
        rows.append(
            f"| {arch}/{shape} | {variant} | "
            f"{br['bound_s']:.4f}s ({br['dominant']}) | "
            f"{vr['bound_s']:.4f}s ({vr['dominant']}) | "
            f"{(vr['bound_s'] / br['bound_s'] - 1) * 100:+.1f}% | "
            f"{b['model_flops']['roofline_fraction']:.4f} -> "
            f"{v['model_flops']['roofline_fraction']:.4f} |")
    if not rows:
        return "(pending)"
    return "\n".join(
        ["| cell | variant | baseline bound | variant bound | Δ | "
         "roofline frac |", "|---|---|---|---|---|---|"] + rows)


def main() -> None:
    exp = ROOT / "EXPERIMENTS.md"
    text = exp.read_text()
    text = _replace(text, "DRYRUN_SUMMARY", dryrun_summary())
    text = _replace(text, "ROOFLINE_TABLE", table_markdown())
    text = _replace(text, "ROOFLINE_OBSERVATIONS", observations())
    text = _replace(text, "PERF_LOG", perf_log() + "\n\n" + PERF_NARRATIVE)
    exp.write_text(text)
    print("EXPERIMENTS.md updated")


def _replace(text: str, marker: str, content: str) -> str:
    tag = f"<!-- {marker} -->"
    begin = f"<!-- BEGIN {marker} -->"
    end = f"<!-- END {marker} -->"
    block = f"{begin}\n{content}\n{end}"
    if begin in text:
        pre = text.split(begin)[0]
        post = text.split(end)[1]
        return pre + block + post
    return text.replace(tag, block)


PERF_NARRATIVE = "<!-- narrative is maintained by hand below -->"

if __name__ == "__main__":
    main()
