"""One benchmark per paper table/figure (HopsFS §7). Each function returns
rows of (name, us_per_call, derived-claim-string).

Cluster-scale results come from the measured-cost DES (see DESIGN.md §2);
functional numbers are wall-clock on the real store.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import (READ_COMMITTED, HopsFSOps, MetadataStore, SubtreeOps,
                        Transaction, format_fs)
from repro.core.cluster_sim import HDFSSim, HopsFSSim, profile_ops
from repro.core.costmodel import (capacity_headline,
                                  create_depth10_roundtrips, table2, table3)
from repro.core.hdfs_baseline import HDFSNamenode
from repro.core.tables import make_inode
from repro.core.workload import (NamespaceSpec, SpotifyWorkload,
                                 SyntheticNamespace, TABLE1_MIX)

Row = Tuple[str, float, str]
_PROFILES = None
_NS = None


def _profiles():
    global _PROFILES
    if _PROFILES is None:
        _PROFILES = profile_ops()
    return _PROFILES


def _ns():
    global _NS
    if _NS is None:
        _NS = SyntheticNamespace(NamespaceSpec(), n_dirs=40)
    return _NS


def _timeit(fn, n=1000) -> float:
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6


# ---------------------------------------------------------------------------
# Table 1: workload mix
# ---------------------------------------------------------------------------

def bench_table1_workload_mix(quick=False) -> List[Row]:
    wl = SpotifyWorkload(_ns(), seed=3)
    hist = wl.mix_histogram(20_000 if quick else 100_000)
    read = hist.get("read", 0)
    stat = hist.get("stat", 0)
    ls = hist.get("ls", 0)
    ro = read + stat + ls + hist.get("content_summary", 0)
    return [("table1.read_pct", 0.0, f"{read:.1f}% (paper 68.73%)"),
            ("table1.stat_pct", 0.0, f"{stat:.1f}% (paper 17%)"),
            ("table1.ls_pct", 0.0, f"{ls:.1f}% (paper 9%)"),
            ("table1.readonly_pct", 0.0, f"{ro:.1f}% (paper ~95%)")]


# ---------------------------------------------------------------------------
# Fig 2a: relative cost of DB access paths
# ---------------------------------------------------------------------------

def bench_fig2a_opcosts(quick=False) -> List[Row]:
    store = MetadataStore(n_datanodes=4, n_partitions=64)
    format_fs(store)
    t = store.table("inode")
    for i in range(5000):
        t.put(make_inode(10 + i, 3 + (i % 37), f"f{i}", False))

    def pk():
        txn = Transaction(store, partition_hint=("inode", 3))
        txn.read("inode", (3, "f0"), READ_COMMITTED)
        txn.abort()

    def batch():
        txn = Transaction(store, partition_hint=("inode", 3))
        txn.read_batch([("inode", (3 + (i % 37), f"f{i}"),
                         READ_COMMITTED) for i in range(10)])
        txn.abort()

    def ppis():
        txn = Transaction(store, partition_hint=("inode", 3))
        txn.ppis("inode", "parent_id", 3)
        txn.abort()

    def iscan():
        txn = Transaction(store, partition_hint=("inode", 3))
        txn.index_scan("inode", "parent_id", 3)
        txn.abort()

    def fts():
        txn = Transaction(store, partition_hint=("inode", 3))
        txn.full_scan("inode", lambda r: r["name"] == "f17")
        txn.abort()

    n = 100 if quick else 400
    us = {k: _timeit(f, n) for k, f in
          [("pk", pk), ("batch", batch), ("ppis", ppis),
           ("is", iscan), ("fts", fts)]}
    order_ok = us["ppis"] < us["fts"] and us["pk"] < us["fts"]
    return [(f"fig2a.{k}", v, f"{v / us['pk']:.1f}x PK")
            for k, v in us.items()] + \
        [("fig2a.hierarchy", 0.0,
          f"PPIS<FTS and PK<FTS: {order_ok} (paper Fig 2a)")]


# ---------------------------------------------------------------------------
# Fig 6: raw per-op throughput vs namenodes
# ---------------------------------------------------------------------------

class _SingleOpWorkload:
    def __init__(self, op_name: str, ns):
        self._wl = SpotifyWorkload(ns)
        self.op = op_name

    def next_op(self):
        from repro.core.workload import WorkloadOp
        if self.op == "read":
            return WorkloadOp("read", self._wl.ns.sample_file(self._wl.rng))
        if self.op == "ls":
            return WorkloadOp("ls", self._wl.ns.sample_dir(self._wl.rng),
                              on_dir=True)
        if self.op == "stat":
            return WorkloadOp("stat", self._wl.ns.sample_file(self._wl.rng))
        if self.op == "create":
            self._wl._create_seq += 1
            return WorkloadOp(
                "create",
                f"{self._wl.ns.sample_dir(self._wl.rng)}"
                f"/w{self._wl._create_seq:08d}")
        raise KeyError(self.op)


def bench_fig6_raw_throughput(quick=False) -> List[Row]:
    """Paper Fig 6 sweeps up to 60 namenodes per op; we sweep to 24 (the
    shape — stacked per-NN increments vs the flat HDFS bar — is the claim)."""
    rows: List[Row] = []
    horizon = 0.4 if quick else 0.5
    nns = (1, 4, 12) if quick else (1, 4, 12, 24)
    for op in ("read", "stat", "ls", "create"):
        hdfs = HDFSSim()
        hdfs.start_clients(600, _SingleOpWorkload(op, _ns()))
        h_tp = hdfs.run(horizon).throughput
        best = 0.0
        for nn in nns:
            sim = HopsFSSim(n_namenodes=nn, n_ndb=8, profiles=_profiles())
            sim.start_clients(min(3600, 300 * nn),
                              _SingleOpWorkload(op, _ns()))
            tp = sim.run(horizon).throughput
            rows.append((f"fig6.{op}.hops_{nn}nn", 0.0, f"{tp:,.0f} ops/s"))
            best = max(best, tp)
        rows.append((f"fig6.{op}.hdfs", 0.0, f"{h_tp:,.0f} ops/s"))
        rows.append((f"fig6.{op}.speedup", 0.0,
                     f"{best / h_tp:.2f}x (paper: HopsFS wins on common "
                     "ops given enough namenodes)"))
    return rows


# ---------------------------------------------------------------------------
# Fig 7: subtree op latency vs directory size
# ---------------------------------------------------------------------------

def bench_fig7_subtree(quick=False) -> List[Row]:
    rows: List[Row] = []
    sizes = (250, 1000) if quick else (250, 1000, 3000)
    ratios = []
    for n_files in sizes:
        store = MetadataStore(n_datanodes=4)
        format_fs(store)
        fs = HopsFSOps(store, 0)
        st = SubtreeOps(fs, batch_size=500)
        fs.mkdir("/big")
        for i in range(n_files):
            fs.create(f"/big/f{i:06d}")
        t0 = time.perf_counter()
        st.delete_subtree("/big")
        hops_s = time.perf_counter() - t0

        hdfs = HDFSNamenode()
        hdfs.mkdir("/big")
        for i in range(n_files):
            hdfs.create(f"/big/f{i:06d}")
        t0 = time.perf_counter()
        hdfs.delete("/big")
        hdfs_s = time.perf_counter() - t0
        ratios.append(hops_s / max(hdfs_s, 1e-9))
        rows.append((f"fig7.delete.{n_files}files",
                     hops_s * 1e6, f"HopsFS {hops_s*1e3:.1f}ms vs "
                     f"HDFS {hdfs_s*1e3:.1f}ms "
                     f"({hops_s/max(hdfs_s,1e-9):.0f}x slower)"))
    rows.append(("fig7.claim", 0.0,
                 f"HopsFS subtree delete ~{np.mean(ratios):.0f}x slower than "
                 "in-heap HDFS (paper: 'an order of magnitude' — our "
                 "functional store amplifies the gap since HDFS's side is a "
                 "bare dict walk; direction + batched-txn structure match)"))
    return rows


# ---------------------------------------------------------------------------
# Table 2: capacity
# ---------------------------------------------------------------------------

def bench_table2_capacity(quick=False) -> List[Row]:
    rows: List[Row] = []
    # measured bytes/file from the live store
    store = MetadataStore(n_datanodes=4)
    format_fs(store)
    fs = HopsFSOps(store, 0)
    fs.mkdir("/m")
    before = store.memory_bytes()
    n = 500
    for i in range(n):
        f = f"/m/f{i:04d}"
        fs.create(f)
        b1 = fs.add_block(f).value
        b2 = fs.add_block(f).value
        fs.complete_block(f, b1, size=1)
        fs.complete_block(f, b2, size=1)
    per_file = (store.memory_bytes() - before) / n
    rows.append(("table2.measured_bytes_per_file", 0.0,
                 f"{per_file:.0f} B/file live-store (paper: 2420 B "
                 "incl. NDB indexes/padding via sizer)"))
    for label, vals in table2().items():
        h = "DNS" if vals["hdfs"] is None else f"{vals['hdfs']/1e6:.1f}M"
        rows.append((f"table2.{label.replace(' ', '')}", 0.0,
                     f"HDFS {h} vs HopsFS {vals['hopsfs']/1e6:.1f}M files"))
    head = capacity_headline()
    rows.append(("table2.headline", 0.0,
                 f"{head['ratio']:.0f}x more metadata (paper: 24x; "
                 f"10.8B files at 24TB)"))
    return rows


# ---------------------------------------------------------------------------
# Fig 8: industrial workload throughput
# ---------------------------------------------------------------------------

def bench_fig8_industrial(quick=False) -> List[Row]:
    rows: List[Row] = []
    horizon = 0.5 if quick else 0.8
    hdfs = HDFSSim()
    hdfs.start_clients(1500, SpotifyWorkload(_ns()))
    hdfs_tp = hdfs.run(horizon).throughput
    rows.append(("fig8.hdfs", 0.0, f"{hdfs_tp:,.0f} ops/s"))
    grid = [(1, 2, 300), (4, 2, 800), (8, 2, 1500), (8, 4, 1500),
            (12, 4, 2200), (12, 8, 2200)]
    if quick:
        grid = [(1, 2, 300), (8, 2, 1200), (12, 8, 2000)]
    tp2 = {}
    for nn, ndb, cl in grid:
        sim = HopsFSSim(n_namenodes=nn, n_ndb=ndb, profiles=_profiles())
        sim.start_clients(cl, SpotifyWorkload(_ns()))
        tp = sim.run(horizon).throughput
        tp2[(nn, ndb)] = tp
        rows.append((f"fig8.hops_{nn}nn_{ndb}ndb", 0.0,
                     f"{tp:,.0f} ops/s = {tp / hdfs_tp:.2f}x HDFS"))
    best = max(tp2.values())
    rows.append(("fig8.headline", 0.0,
                 f"{best / hdfs_tp:.2f}x HDFS at 12NN (paper: 2.6x); "
                 "2-NDB saturates ~8NN (paper: levels off)"))
    return rows


# ---------------------------------------------------------------------------
# Fig 9: latency vs concurrent clients
# ---------------------------------------------------------------------------

def bench_fig9_latency(quick=False) -> List[Row]:
    rows: List[Row] = []
    horizon = 0.4 if quick else 0.6
    counts = (100, 400, 1500) if quick else (100, 400, 1000, 2000)
    cross = None
    for n_cl in counts:
        hd = HDFSSim()
        hd.start_clients(n_cl, SpotifyWorkload(_ns()))
        hl = hd.run(horizon).latency_avg() * 1e3
        hs = HopsFSSim(n_namenodes=12, n_ndb=4, profiles=_profiles())
        hs.start_clients(n_cl, SpotifyWorkload(_ns()))
        sl = hs.run(horizon).latency_avg() * 1e3
        if cross is None and sl < hl:
            cross = n_cl
        rows.append((f"fig9.{n_cl}clients", 0.0,
                     f"HDFS {hl:.2f}ms vs HopsFS {sl:.2f}ms"))
    rows.append(("fig9.crossover", 0.0,
                 f"HopsFS wins beyond ~{cross} clients "
                 "(paper: >400 clients)"))
    return rows


# ---------------------------------------------------------------------------
# Fig 10: p99 latencies at 50% load
# ---------------------------------------------------------------------------

def bench_fig10_p99(quick=False) -> List[Row]:
    horizon = 0.5 if quick else 1.0
    sim = HopsFSSim(n_namenodes=12, n_ndb=4, profiles=_profiles())
    sim.start_clients(360, SpotifyWorkload(_ns()))   # 30/NN (paper)
    res = sim.run(horizon)
    hd = HDFSSim()
    hd.start_clients(360, SpotifyWorkload(_ns()))
    rh = hd.run(horizon)
    return [("fig10.hops_p99_ms", 0.0,
             f"{res.latency_pct(99) * 1e3:.1f}ms (paper: 9-76ms per op)"),
            ("fig10.hdfs_p99_ms", 0.0,
             f"{rh.latency_pct(99) * 1e3:.1f}ms (paper: 1-6ms)"),
            ("fig10.hops_avg_ms", 0.0, f"{res.latency_avg() * 1e3:.2f}ms"),
            ("fig10.hdfs_avg_ms", 0.0, f"{rh.latency_avg() * 1e3:.2f}ms")]


# ---------------------------------------------------------------------------
# Fig 11: failover
# ---------------------------------------------------------------------------

def bench_fig11_failover(quick=False) -> List[Row]:
    horizon = 3.0 if quick else 6.0
    hs = HopsFSSim(n_namenodes=4, n_ndb=4, profiles=_profiles())
    hs.start_clients(400, SpotifyWorkload(_ns()))
    hs.sim.after(1.0, lambda: hs.kill_namenode(0))
    hs.sim.after(2.0, lambda: hs.restart_namenode(0))
    res = hs.run(horizon)
    secs = dict(res.timeline)
    hops_zero = sum(1 for s in range(int(horizon))
                    if secs.get(s, 0) == 0)
    dip = min(secs.get(s, 0) for s in (1, 2)) / max(secs.get(0, 1), 1)

    hd = HDFSSim()
    hd.start_clients(400, SpotifyWorkload(_ns()))
    hd.sim.after(1.0, hd.kill_active)
    rh = hd.run(horizon)
    hsecs = dict(rh.timeline)
    hdfs_zero = sum(1 for s in range(1, int(horizon))
                    if hsecs.get(s, 0) == 0)
    return [("fig11.hopsfs_zero_seconds", 0.0,
             f"{hops_zero} s of zero throughput (paper: none); "
             f"dip to {dip * 100:.0f}% during failover"),
            ("fig11.hdfs_zero_seconds", 0.0,
             f"{hdfs_zero} s of zero throughput "
             f"(paper: 8-10 s failover)")]


# ---------------------------------------------------------------------------
# Fig 12/13: optimization ablations (DAT / ADP / inode-hint cache)
# ---------------------------------------------------------------------------

def bench_fig12_13_ablations(quick=False) -> List[Row]:
    rows: List[Row] = []
    # round-trip ablation at depth 10 (paper's analysis + our measurement)
    ex = create_depth10_roundtrips()
    rows.append(("fig13.create_cache_saving", 0.0,
                 f"{ex['improvement_pct']}% fewer RTs at depth 10 "
                 "(paper: ~58%)"))
    read_miss = table3("read", 10, cached=False).total
    read_hit = table3("read", 10, cached=True).total
    rows.append(("fig12.read_cache_saving", 0.0,
                 f"{100 * (read_miss - read_hit) / read_miss:.0f}% fewer RTs "
                 "(paper: ~68% throughput gain)"))
    # DES throughput with each optimization removed
    horizon = 0.4 if quick else 0.8
    variants = {
        "full": profile_ops(),
        "no_cache": profile_ops(use_cache=False),
        "no_dat": profile_ops(distribution_aware=False),
        "no_adp": profile_ops(adp=False),
    }
    tps = {}
    for name, prof in variants.items():
        sim = HopsFSSim(n_namenodes=12, n_ndb=4, profiles=prof)
        sim.start_clients(1800, SpotifyWorkload(_ns()))
        tps[name] = sim.run(horizon).throughput
        rows.append((f"fig12_13.tp_{name}", 0.0,
                     f"{tps[name]:,.0f} ops/s"))
    rows.append(("fig12_13.cache_gain", 0.0,
                 f"+{100 * (tps['full'] / tps['no_cache'] - 1):.0f}% from "
                 "hint cache (paper: 58-68%)"))
    rows.append(("fig12_13.adp_gain", 0.0,
                 f"+{100 * (tps['full'] / tps['no_adp'] - 1):.0f}% from "
                 "ADP partition pruning"))
    return rows


# ---------------------------------------------------------------------------
# Table 3: cost-model validation
# ---------------------------------------------------------------------------

def bench_table3_costmodel(quick=False) -> List[Row]:
    rows: List[Row] = []
    depths = (4, 10) if quick else (3, 6, 10, 14)
    mismatches = 0
    total = 0
    for depth in depths:
        store = MetadataStore(n_datanodes=4)
        format_fs(store)
        warm = HopsFSOps(store, 0)
        d = "/" + "/".join(f"l{i}" for i in range(depth - 1))
        warm.mkdirs(d)
        warm.create(d + "/f")
        warm.stat(d + "/f")
        cold = HopsFSOps(store, 1, use_cache=False)
        cases = [
            ("read", lambda o: o.get_block_locations(d + "/f")),
            ("stat", lambda o: o.stat(d + "/f")),
            ("ls", lambda o: o.listing(d + "/f")),
            ("mkdir", lambda o, k=[0]: (k.__setitem__(0, k[0] + 1),
                                        o.mkdir(f"{d}/m{id(o)}{k[0]}"))[1]),
            ("create", lambda o, k=[0]: (k.__setitem__(0, k[0] + 1),
                                         o.create(f"{d}/c{id(o)}{k[0]}"))[1]),
            ("addblk", lambda o: o.add_block(d + "/f")),
            ("chmod", lambda o: o.chmod_file(d + "/f", 0o640)),
        ]
        for name, fn in cases:
            for cached, ops in ((True, warm), (False, cold)):
                measured = fn(ops).cost.round_trips
                expect = table3("ls" if name == "ls" else name, depth,
                                cached=cached,
                                is_dir=False).total
                total += 1
                delta = measured - expect
                if abs(delta) > 1:
                    mismatches += 1
                if depth == 10:
                    tag = "hit" if cached else "miss"
                    rows.append((f"table3.{name}.{tag}.d10", 0.0,
                                 f"measured {measured} vs paper {expect} "
                                 f"(Δ{delta:+d})"))
    rows.append(("table3.summary", 0.0,
                 f"{total - mismatches}/{total} op×depth×cache cells within "
                 "±1 RT of Table 3"))
    return rows


# ---------------------------------------------------------------------------
# (ours) checkpoint-manifest metadata throughput
# ---------------------------------------------------------------------------

def bench_ckpt_metadata(quick=False) -> List[Row]:
    from repro.metaplane import MetadataPlane
    plane = MetadataPlane()
    plane.open_job("bigjob")
    n = 200 if quick else 1000
    base = plane.begin_checkpoint("bigjob", 1)
    t0 = time.perf_counter()
    for i in range(n):
        plane.add_shard(base, f"layers/{i % 96}/w", i)
    plane.commit_checkpoint("bigjob", 1)
    el = time.perf_counter() - t0
    man = plane.manifest("bigjob", 1)
    return [("ckpt.manifest_rows_per_s", el / n * 1e6,
             f"{n / el:,.0f} shard-rows/s; commit = 1 subtree rename; "
             f"manifest complete={man.complete}")]
