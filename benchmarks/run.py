"""Benchmark harness: one function per paper table/figure (+ roofline).
Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig8]
"""
from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks import paper_benches as pb            # noqa: E402
from benchmarks.roofline import bench_roofline        # noqa: E402
from benchmarks.trace_replay import bench_trace_replay  # noqa: E402

BENCHES = [
    ("table1", pb.bench_table1_workload_mix),
    ("fig2a", pb.bench_fig2a_opcosts),
    ("fig6", pb.bench_fig6_raw_throughput),
    ("fig7", pb.bench_fig7_subtree),
    ("table2", pb.bench_table2_capacity),
    ("fig8", pb.bench_fig8_industrial),
    ("fig9", pb.bench_fig9_latency),
    ("fig10", pb.bench_fig10_p99),
    ("fig11", pb.bench_fig11_failover),
    ("fig12_13", pb.bench_fig12_13_ablations),
    ("table3", pb.bench_table3_costmodel),
    ("trace_replay", bench_trace_replay),
    ("ckpt", pb.bench_ckpt_metadata),
    ("roofline", bench_roofline),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    print("name,us_per_call,derived")
    for name, fn in BENCHES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        try:
            rows = fn(quick=args.quick)
        except Exception as e:  # pragma: no cover
            print(f"{name}.ERROR,0,{type(e).__name__}: {e}")
            continue
        for rname, us, derived in rows:
            print(f"{rname},{us:.2f},\"{derived}\"")
        print(f"{name}.elapsed,{(time.time() - t0) * 1e6:.0f},"
              f"\"{time.time() - t0:.1f}s wall\"")


if __name__ == "__main__":
    main()
