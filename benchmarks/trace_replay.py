"""Spotify-trace replay driver — the paper's Fig 7 throughput-scaling
methodology (§7.2).

Replays a fixed Spotify-style trace (§7.2 op mix: ~67% getBlockLocations,
~12% listStatus, ...) through the batched multi-namenode request pipeline at
several namenode counts and writes a Fig 7-style throughput-vs-namenodes
JSON. Two layers are exercised:

  * the **DES** (`BatchedHopsFSSim`): cluster-scale throughput/latency with
    per-op DB round-trip profiles measured from the functional store;
  * the **functional pipeline**, driven through the typed `DFSClient`
    facade (`DFSClient.run_trace` -> `RequestPipeline`): real transactions
    on the real store, proving the batched executor's round-trip savings
    and that batched == sequential final state — on the Spotify mix AND
    on the write-heavy block-layer mix (`WRITE_HEAVY_MIX`), where the
    lease-ordered grouped block-write path carries the batched share
    (`batched_write_fraction`). Four execution modes per mix: sequential,
    reactive, planned (closed-loop: response-piggybacked client hint
    cache + adaptive windows) and planned+concurrent (per-window worker
    fleet with lease-ordered dealing), with client hint-cache hit-rate
    telemetry.

A ``failover`` section (§7.6, Fig 11) kills one of four namenodes
mid-replay on the DES with fine-grained timeline bins and reports the
throughput dip depth, time/ops to recovery and the number of zero-
throughput bins (paper: none — clients fail over transparently).

  PYTHONPATH=src python -m benchmarks.trace_replay [--quick] \
      [--out BENCH_throughput.json] [--namenodes 1,4,16] [--batch-size 16]

Output schema is documented in docs/BENCHMARKS.md.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path
from typing import Dict, List, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import (DFSClient, MetadataStore, NamenodeCluster,
                        format_fs, materialize_namespace, namespace_snapshot)
from repro.core.cluster_sim import BatchedHopsFSSim, profile_ops
from repro.core.workload import (NamespaceSpec, SPOTIFY_TRACE_MIX,
                                 SyntheticNamespace, TraceReplay,
                                 WRITE_HEAVY_MIX, make_spotify_trace)

Row = Tuple[str, float, str]

DEFAULT_OUT = Path(__file__).resolve().parents[1] / "BENCH_throughput.json"


def replay_des(trace, profiles, *, n_namenodes: int, n_ndb: int = 8,
               batch_size: int = 16, clients_per_nn: int = 200,
               horizon: float = 0.3, seed: int = 1,
               planned: bool = False) -> Dict:
    """Replay the trace at one namenode count on the batched-pipeline DES
    (``planned=True`` mirrors the client-side batch planner: partition-
    aligned, type-pure batch pulls instead of FIFO slices)."""
    sim = BatchedHopsFSSim(n_namenodes=n_namenodes, n_ndb=n_ndb,
                           profiles=profiles, batch_size=batch_size,
                           seed=seed, planned=planned)
    sim.start_clients(clients_per_nn * n_namenodes, TraceReplay(trace))
    res = sim.run(horizon)
    return {
        "namenodes": n_namenodes,
        "clients": clients_per_nn * n_namenodes,
        "planned": planned,
        "throughput_ops_s": round(res.throughput, 1),
        "latency_avg_ms": round(res.latency_avg() * 1e3, 3),
        "latency_p99_ms": round(res.latency_pct(99) * 1e3, 3),
        "completed_ops": res.completed,
        "batches_executed": sim.batches_executed,
        "batched_ops": sim.batched_ops,
        "per_nn_ops": list(sim.nn_ops_completed),
    }


def functional_batching_report(trace, *, n_namenodes: int = 4,
                               batch_size: int = 16,
                               n_dirs: int = 20) -> Dict:
    """Run the *functional* pipeline four ways on identical stores —
    sequential (batch=1), reactive (FIFO batches, opportunistic grouping),
    planned (closed-loop client-side batch planner: partition-aligned,
    type-sorted batches with grouped reads AND writes, response-warmed
    client hint cache, adaptive windows) and planned+concurrent (one
    worker per namenode within each window barrier) — and report measured
    round-trip savings, batched fractions, local round-trip share,
    client hint-cache hit rates, and final-state equivalence. Ties the
    DES's collapse model to real transactions; driven through the typed
    `DFSClient` facade."""
    from repro.core import PlannedRequestPipeline

    def build():
        store = MetadataStore(n_datanodes=4)
        format_fs(store)
        cluster = NamenodeCluster(store, n_namenodes)
        ns = SyntheticNamespace(NamespaceSpec(), n_dirs=n_dirs,
                                files_per_dir=4)
        materialize_namespace(cluster.namenodes[0], ns)
        return store, cluster

    store_seq, cluster = build()
    seq = DFSClient(cluster).run_trace(trace, batch_size=1)
    store_rea, cluster = build()
    rea = DFSClient(cluster).run_trace(trace, batch_size=batch_size)
    # start the adaptive window small relative to the trace so the closed
    # loop actually cycles (plan -> execute -> absorb -> replan) several
    # times; the controller grows it from there
    window0 = batch_size * 8
    store_pln, cluster = build()
    planned_pipe = PlannedRequestPipeline(cluster, batch_size=batch_size,
                                          window=window0)
    pln = planned_pipe.run(trace)
    plan = planned_pipe.plan_report
    store_cc, cluster = build()
    cc_pipe = PlannedRequestPipeline(cluster, batch_size=batch_size,
                                     concurrent=True, window=window0)
    cc = cc_pipe.run(trace)
    cc_plan = cc_pipe.plan_report
    # multi-NN dispatch differs between runs, so physical ids and per-NN
    # mtime clocks differ; compare the logical namespace instead (the
    # strict single-NN full-state equality lives in the test suite)
    snap_seq = namespace_snapshot(store_seq)
    state_equal = (snap_seq == namespace_snapshot(store_rea)
                   == namespace_snapshot(store_pln)
                   == namespace_snapshot(store_cc))
    rt_seq = seq.total_cost.round_trips
    rt_rea = rea.total_cost.round_trips
    rt_pln = pln.total_cost.round_trips
    rt_cc = cc.total_cost.round_trips

    def pct(saved, base):
        return round(100 * (1 - saved / base), 2) if base else 0.0

    def hint_telemetry(rep, cache):
        return {
            "client_hits": rep.client_hits if rep else 0,
            "fallback_hits": rep.client_fallback_hits if rep else 0,
            "misses": rep.client_misses if rep else 0,
            "hit_rate": round(rep.hint_hit_rate, 3) if rep else 0.0,
            "stale_overwrites": cache.stale_overwrites,
            "invalidations": cache.invalidations,
            "entries": cache.entries,
        }

    return {
        "batch_size": batch_size,
        "ops": len(seq.outcomes),
        "ok": pln.ok,
        "failed": pln.failed,
        "sequential_round_trips": rt_seq,
        "batched_round_trips": rt_rea,       # back-compat: reactive mode
        "reactive_round_trips": rt_rea,
        "planned_round_trips": rt_pln,
        "round_trip_savings_pct": pct(rt_rea, rt_seq),
        "planned_savings_pct": pct(rt_pln, rt_seq),
        "planned_vs_reactive_savings_pct": pct(rt_pln, rt_rea),
        "batched_fraction": round(rea.batched_fraction, 3),
        "planned_batched_fraction": round(pln.batched_fraction, 3),
        "batched_read_fraction": round(pln.batched_read_fraction, 3),
        "batched_write_fraction": round(pln.batched_write_fraction, 3),
        "local_rt_fraction": {
            "sequential": round(seq.local_rt_fraction, 3),
            "reactive": round(rea.local_rt_fraction, 3),
            "planned": round(pln.local_rt_fraction, 3),
            "planned_concurrent": round(cc.local_rt_fraction, 3),
        },
        "planner": {
            "planned_ops": plan.planned_ops if plan else 0,
            "pinned_ops": plan.pinned_ops if plan else 0,
            "lease_ordered_ops": plan.lease_ordered_ops if plan else 0,
            "windows": plan.windows if plan else 0,
            "window_sizes": list(plan.window_sizes) if plan else [],
            "kernel_launches": plan.kernel_launches if plan else 0,
            "predicted_local_rt_share":
                round(plan.predicted_local_share, 3) if plan else 0.0,
        },
        # closed-loop client hint-cache telemetry (deterministic planned
        # run): hits on the response-warmed client cache vs fallback hits
        # on the merged namenode caches vs misses
        "hint_cache": hint_telemetry(plan, planned_pipe.client_cache),
        # the concurrent planned mode: per-window worker fleet, lifted
        # mutation pinning (lease-ordered dealing), same final namespace
        "planned_concurrent": {
            "ok": cc.ok,
            "failed": cc.failed,
            "round_trips": rt_cc,
            "vs_reactive_savings_pct": pct(rt_cc, rt_rea),
            "batched_fraction": round(cc.batched_fraction, 3),
            "batched_read_fraction": round(cc.batched_read_fraction, 3),
            "batched_write_fraction": round(cc.batched_write_fraction, 3),
            "lease_ordered_ops":
                cc_plan.lease_ordered_ops if cc_plan else 0,
            "pinned_ops": cc_plan.pinned_ops if cc_plan else 0,
            "hint_cache": hint_telemetry(cc_plan, cc_pipe.client_cache),
        },
        "state_matches_sequential": state_equal,
    }


def failover_report(trace, profiles, *, n_namenodes: int = 4,
                    batch_size: int = 16, horizon: float = 0.3,
                    kill_frac: float = 0.4, restart_frac: float = 0.7,
                    timeline_bin: float = 0.02, seed: int = 1) -> Dict:
    """Kill one of ``n_namenodes`` mid-replay on the batched DES, restart
    it later, and measure the throughput dip and recovery (§7.6: HopsFS
    keeps serving through a namenode failure — surviving namenodes drain
    the shared queue and clients requeue in-flight batches, so the dip is
    a brief capacity loss, never HDFS-style downtime)."""
    sim = BatchedHopsFSSim(n_namenodes=n_namenodes, n_ndb=8,
                           profiles=profiles, batch_size=batch_size,
                           seed=seed, timeline_bin=timeline_bin)
    sim.start_clients(200 * n_namenodes, TraceReplay(trace))
    kill_at = round(kill_frac * horizon, 4)
    restart_at = round(restart_frac * horizon, 4)
    victim = 0
    sim.schedule_kill(kill_at, victim)
    sim.schedule_restart(restart_at, victim)
    res = sim.run(horizon)
    counts = dict(res.timeline)
    n_bins = int(horizon / timeline_bin)
    series = [counts.get(b * timeline_bin, 0) for b in range(n_bins)]
    kill_bin = int(kill_at / timeline_bin)
    pre = series[1:kill_bin]             # drop the cold-start bin
    steady = sum(pre) / len(pre) if pre else 0.0
    post = series[kill_bin:]
    dip = min(post) if post else 0
    # recovery = first post-kill bin back at >=90% of steady throughput
    recovery_bin = next(
        (kill_bin + i for i, c in enumerate(post) if c >= 0.9 * steady),
        None)
    recovered = recovery_bin is not None
    recovery_s = (round((recovery_bin - kill_bin + 1) * timeline_bin, 4)
                  if recovered else None)
    ops_to_recovery = (sum(series[kill_bin:recovery_bin + 1])
                       if recovered else sum(post))
    return {
        "n_namenodes": n_namenodes,
        "killed_namenode": victim,
        "kill_at_s": kill_at,
        "restart_at_s": restart_at,
        "horizon_s": horizon,
        "timeline_bin_s": timeline_bin,
        "steady_ops_per_bin": round(steady, 1),
        "dip_ops_per_bin": dip,
        "dip_depth_pct": (round(100 * (1 - dip / steady), 1)
                          if steady else 0.0),
        "recovered": recovered,
        "recovery_s": recovery_s,
        "ops_to_recovery": ops_to_recovery,
        "zero_bins_after_kill": sum(1 for c in post if c == 0),
        "requeued_ops": sim.failed_ops,
        "completed_ops": res.completed,
        "fault_events": [[round(t, 4), action, nn]
                         for t, action, nn in sim.fault_events],
    }


def elasticity_report(trace, profiles, *, batch_size: int = 16,
                      horizon: float = 0.3, timeline_bin: float = 0.02,
                      scale_out_frac: float = 0.3,
                      scale_in_frac: float = 0.7,
                      phase_ops: int = 600, seed: int = 1) -> Dict:
    """The elastic-pool benchmark, two layers like everything else here.

    **DES**: replay on the batched planned pipeline starting at 2
    namenodes, scale out to 4 mid-run and back in to 2 later, with
    fine-grained timeline bins — throughput must RISE through scale-out
    with no zero-throughput bins (joiners pull from the shared queue
    immediately) and return to the 2-NN steady state after scale-in.

    **Functional**: three phases of ONE continuous Spotify stream on the
    real store. Phase A (2 NNs, fixed) measures the steady-state client
    hint hit rate; phase B runs with an ``ElasticNamenodePool`` attached,
    which scales 2→4 under queue pressure (joiners pre-warmed from the
    client cache); idle ticks then scale back 4→2, warm-migrating the
    victims' caches; phase C measures the post-migration hit rate — the
    warm-migration claim is that it stays within a few percent of phase
    A's. The full three-phase namespace must equal a fixed-size
    sequential replay of the same trace (scale events move WORK, never
    metadata)."""
    from repro.core import PlannedRequestPipeline, RequestPipeline
    from repro.core.pool import ElasticNamenodePool
    from repro.core.hint_cache import InodeHintCache
    from repro.core.workload import make_phased_trace

    # -- DES: throughput through scale-out 2->4 and scale-in 4->2 ------
    base_nns, peak_nns = 2, 4
    sim = BatchedHopsFSSim(n_namenodes=base_nns, n_ndb=8,
                           profiles=profiles, batch_size=batch_size,
                           seed=seed, planned=True,
                           timeline_bin=timeline_bin)
    # client population sized for the PEAK fleet, so the base fleet is
    # genuinely oversubscribed and scale-out has headroom to absorb
    sim.start_clients(200 * peak_nns, TraceReplay(trace))
    out_at = round(scale_out_frac * horizon, 4)
    in_at = round(scale_in_frac * horizon, 4)
    sim.schedule_scale_out(out_at, peak_nns - base_nns)
    sim.schedule_scale_in(in_at, peak_nns - base_nns)
    res = sim.run(horizon)
    counts = dict(res.timeline)
    n_bins = int(horizon / timeline_bin)
    series = [counts.get(b * timeline_bin, 0) for b in range(n_bins)]
    out_bin = int(out_at / timeline_bin)
    in_bin = int(in_at / timeline_bin)
    pre = series[1:out_bin]               # drop the cold-start bin
    steady = sum(pre) / len(pre) if pre else 0.0
    # settled scaled-phase throughput: skip the ramp bin after scale-out
    scaled_bins = series[out_bin + 1:in_bin]
    scaled = (sum(scaled_bins) / len(scaled_bins) if scaled_bins else 0.0)
    post = series[in_bin:]
    # recovery after scale-in = first bin back DOWN to within 25% of the
    # 2-NN steady state (the fleet sheds capacity, so "recovered" means
    # settled, not restored)
    rec_bin = next((in_bin + i for i, c in enumerate(post)
                    if c <= 1.25 * steady), None)
    recovered = rec_bin is not None

    # -- functional: warm migration on the real store ------------------
    def build():
        store = MetadataStore(n_datanodes=4)
        format_fs(store)
        cluster = NamenodeCluster(store, base_nns)
        ns = SyntheticNamespace(NamespaceSpec(), n_dirs=20,
                                files_per_dir=4)
        materialize_namespace(cluster.namenodes[0], ns)
        return store, cluster, ns

    store, cluster, ns = build()
    full, bounds = make_phased_trace(ns, [phase_ops] * 3, seed=5)
    a, b, c = (full[:bounds[0]], full[bounds[0]:bounds[1]],
               full[bounds[1]:])
    cache = InodeHintCache()
    window = batch_size * 8

    def run_phase(wops, pool=None):
        pipe = PlannedRequestPipeline(cluster, batch_size=batch_size,
                                      window=window, client_cache=cache,
                                      adaptive=False, pool=pool)
        stats = pipe.run(wops)
        return stats, pipe.plan_report

    stats_a, rep_a = run_phase(a)
    pool = ElasticNamenodePool(cluster, min_namenodes=base_nns,
                               max_namenodes=peak_nns, high_load=60,
                               low_load=20, hysteresis=2, cooldown=2)
    pool.register_client_cache(cache)
    stats_b, rep_b = run_phase(b, pool=pool)
    # drain: idle control rounds scale the fleet back in, warm-migrating
    # each victim's hint cache to the survivors
    for _ in range(32):
        if len(cluster.alive_namenodes()) <= base_nns:
            break
        pool.tick(queue_depth=0)
    stats_c, rep_c = run_phase(c)
    ok = stats_a.ok + stats_b.ok + stats_c.ok
    failed = stats_a.failed + stats_b.failed + stats_c.failed

    # fixed-size sequential oracle over the SAME full trace
    store_seq, cluster_seq, _ = build()
    RequestPipeline(cluster_seq, batch_size=1).run(full)
    state_equal = (namespace_snapshot(store)
                   == namespace_snapshot(store_seq))

    before = rep_a.hint_hit_rate
    after = rep_c.hint_hit_rate
    return {
        "n_namenodes_base": base_nns,
        "n_namenodes_peak": peak_nns,
        "scale_out_at_s": out_at,
        "scale_in_at_s": in_at,
        "horizon_s": horizon,
        "timeline_bin_s": timeline_bin,
        "steady_ops_per_bin": round(steady, 1),
        "scaled_ops_per_bin": round(scaled, 1),
        "scale_out_gain_pct": (round(100 * (scaled / steady - 1), 1)
                               if steady else 0.0),
        "zero_bins_during_scale_out": sum(
            1 for v in series[out_bin:in_bin] if v == 0),
        "scale_in_recovered": recovered,
        "scale_in_recovery_s": (round((rec_bin - in_bin + 1)
                                      * timeline_bin, 4)
                                if recovered else None),
        "completed_ops": res.completed,
        "scale_events": [[round(t, 4), action, nn]
                         for t, action, nn in sim.fault_events],
        # functional warm-migration phases
        "phase_ops": phase_ops,
        "ok": ok,
        "failed": failed,
        "hint_hit_rate_before": round(before, 3),
        "hint_hit_rate_after": round(after, 3),
        "hint_hit_rate_drop_pct": (round(100 * (1 - after / before), 1)
                                   if before else 0.0),
        "hint_routed_batches": (rep_b.hint_routed_batches
                                + rep_c.hint_routed_batches),
        "migrated_hint_entries": pool.migrated_entries,
        "pool_scale_outs": pool.scale_outs,
        "pool_scale_ins": pool.scale_ins,
        "pool_events": [[e.t, e.action, e.nn_id, e.migrated_entries]
                        for e in pool.events],
        "state_matches_sequential": state_equal,
    }


def overload_report(*, batch_size: int = 16, n_ops: int = 600,
                    n_tenants: int = 6, n_namenodes: int = 3,
                    deadline_budget: int = 8, deadline_per_op: float = 0.05,
                    delay_ticks: int = 6, seed: int = 9) -> Dict:
    """Gray-failure overload bench (docs/ROBUSTNESS.md): one namenode
    turns SLOW (alive, heartbeating, every batch exchange with it ages
    the shared logical clock — the chaos ``DELAY`` kind) while a Zipf
    s≈1.1 multi-tenant trace with per-op deadlines replays through the
    planned pipeline. Two runs on identical stores:

      * **unprotected** — the plain planned pipeline. The planner keeps
        dealing to the slow namenode, the clock races ahead of the
        deadline horizon, and ops complete LATE (past their deadline —
        work nobody is waiting for).
      * **protected** — admission controller + breaker board. The slow
        namenode sheds already-expired work (``DeadlineExpired``), the
        shed batches trip its circuit breaker, the planner reroutes
        around it, and the clock stops racing. Nothing completes past
        its deadline (admission is checked AFTER the exchange's clock
        advance, so the guarantee is exact, not statistical).

    Goodput is ``ok AND completed_at <= deadline`` on the election
    clock. The protected run must beat the unprotected run on goodput
    and on worst per-tenant p99, with zero late completions. A recovery
    pass (breaker healed, deadlines inert) then re-drives shed ops and
    the final namespace must equal the fault-free sequential oracle —
    shedding loses timeliness, never metadata."""
    from repro.core import (AdmissionController, BreakerBoard, ChaosPlan,
                            DELAY, Fault, FaultInjector, FaultSite,
                            PlannedRequestPipeline, RequestPipeline,
                            stamp_deadlines)
    from repro.core.chaos import RETRYABLE_ERRORS
    from repro.core.workload import make_zipf_tenant_trace

    def build():
        store = MetadataStore(n_datanodes=4)
        format_fs(store)
        cluster = NamenodeCluster(store, n_namenodes)
        ns = SyntheticNamespace(NamespaceSpec(), n_dirs=20,
                                files_per_dir=4)
        materialize_namespace(cluster.namenodes[0], ns)
        return store, cluster, ns

    def fresh_trace(ns, now):
        trace = make_zipf_tenant_trace(ns, n_ops, n_tenants=n_tenants,
                                       seed=seed)
        return stamp_deadlines(trace, now=now, budget=deadline_budget,
                               per_op=deadline_per_op)

    def injector(cluster):
        # one gray-slow namenode: every batch exchange with NN 1 ages the
        # shared clock by ``delay_ticks`` while the slowdown is active
        plan = ChaosPlan(faults=[Fault(FaultSite.BATCH_EXCHANGE, at=4,
                                       victim=1, kind=DELAY,
                                       heal_after=10_000,
                                       delay_ticks=delay_ticks)])
        return FaultInjector(plan, cluster)

    def measure(trace, outcomes, now0):
        ok = late = good = 0
        per_tenant: Dict[str, List[int]] = {}
        shed: Dict[str, int] = {}
        for wop, oc in zip(trace, outcomes):
            if oc.ok:
                ok += 1
                done = oc.result.completed_at
                if wop.deadline is not None and done is not None \
                        and done > wop.deadline:
                    late += 1
                else:
                    good += 1
                per_tenant.setdefault(wop.tenant, []).append(
                    (done if done is not None else now0) - now0)
            else:
                shed[oc.error] = shed.get(oc.error, 0) + 1

        def p99(xs):
            return sorted(xs)[min(len(xs) - 1, int(0.99 * len(xs)))]

        p99s = {t: p99(xs) for t, xs in sorted(per_tenant.items())}
        return {
            "ok": ok,
            "goodput_ops": good,
            "goodput_frac": round(good / len(trace), 3),
            "late_completions": late,
            "failed_by_error": dict(sorted(shed.items())),
            "per_tenant_p99_ticks": p99s,
            "worst_tenant_p99_ticks": max(p99s.values()) if p99s else 0,
            "clock_advance_ticks": None,   # filled by caller
        }

    window = batch_size * 4

    # -- unprotected: naive planned pipeline under the gray failure -----
    store_u, cluster_u, ns_u = build()
    now0_u = cluster_u.election.now
    trace_u = fresh_trace(ns_u, now0_u)
    inj_u = injector(cluster_u)
    inj_u.install()
    try:
        pipe_u = PlannedRequestPipeline(cluster_u, batch_size=batch_size,
                                        window=window, adaptive=False)
        stats_u = pipe_u.run(trace_u)
    finally:
        inj_u.uninstall()
    unprotected = measure(trace_u, stats_u.outcomes, now0_u)
    unprotected["clock_advance_ticks"] = cluster_u.election.now - now0_u

    # -- protected: admission + breakers on an identical cluster --------
    store_p, cluster_p, ns_p = build()
    now0_p = cluster_p.election.now
    trace_p = fresh_trace(ns_p, now0_p)
    admission = AdmissionController(cluster_p.election,
                                    queue_capacity=max(n_ops, 1))
    admission.install(cluster_p)
    board = BreakerBoard(cluster_p.election, failure_threshold=1,
                         reset_after=64)
    inj_p = injector(cluster_p)
    inj_p.install()
    try:
        pipe_p = PlannedRequestPipeline(cluster_p, batch_size=batch_size,
                                        window=window, adaptive=False,
                                        admission=admission,
                                        breakers=board)
        stats_p = pipe_p.run(trace_p)
    finally:
        inj_p.uninstall()
    protected = measure(trace_p, stats_p.outcomes, now0_p)
    protected["clock_advance_ticks"] = cluster_p.election.now - now0_p

    # -- recovery: slow NN healed, deadlines inert — shed ops re-driven;
    # shedding must cost timeliness only, never metadata
    admission.uninstall()
    outcomes = list(stats_p.outcomes)
    todo = [i for i, oc in enumerate(outcomes)
            if not oc.ok and oc.error in RETRYABLE_ERRORS]
    if todo:
        rstats = RequestPipeline(cluster_p, batch_size=1).run(
            [trace_p[i] for i in todo])
        for i, oc in zip(todo, rstats.outcomes):
            outcomes[i] = oc
    cluster_p.recover_leases()
    cluster_p.scrub_leases()

    # fault-free sequential oracle over the same logical trace
    store_o, cluster_o, ns_o = build()
    trace_o = fresh_trace(ns_o, cluster_o.election.now)
    RequestPipeline(cluster_o, batch_size=1).run(trace_o)
    state_equal = (namespace_snapshot(store_p)
                   == namespace_snapshot(store_o))

    rep_p = pipe_p.plan_report
    return {
        "n_namenodes": n_namenodes,
        "slow_namenode": 1,
        "delay_ticks_per_exchange": delay_ticks,
        "n_ops": n_ops,
        "n_tenants": n_tenants,
        "zipf_s": 1.1,
        "batch_size": batch_size,
        "deadline_budget_ticks": deadline_budget,
        "deadline_per_op_ticks": deadline_per_op,
        "unprotected": unprotected,
        "protected": protected,
        "goodput_gain_pct": (
            round(100 * (protected["goodput_ops"]
                         / max(1, unprotected["goodput_ops"]) - 1), 1)),
        "planner_deadline_shed": rep_p.deadline_shed,
        "planner_breaker_rerouted": rep_p.breaker_rerouted,
        "breaker_trips": board.trips,
        "breaker_open_at_end": sorted(board.open_ids()),
        "admission": admission.report(),
        "recovery_redriven_ops": len(todo),
        "state_matches_sequential": state_equal,
    }


def columnar_report(*, batch_size: int = 16, n_ops: int = 600,
                    n_namenodes: int = 4, n_dirs: int = 20) -> Dict:
    """Differential columnar-engine bench (docs/ARCHITECTURE.md, columnar
    section): replay the Spotify mix AND the write-heavy block mix
    through the planned pipeline twice on identical setups — once on the
    dict-backed ``MetadataStore`` oracle, once on the struct-of-arrays
    ``ColumnarMetadataStore`` — then assert the two final states are
    byte-identical (``dump_state`` equality, the oracle lock) and report
    the fused-kernel economics: ONE hintchain launch resolves a whole
    planner window's hint chains and ONE pkval launch validates its
    client-resolved PKs, so launches must be orders of magnitude rarer
    than ops."""
    from repro.core import PlannedRequestPipeline
    from repro.core.columnar import ColumnarMetadataStore

    def build(store_cls):
        store = store_cls(n_datanodes=4)
        format_fs(store)
        cluster = NamenodeCluster(store, n_namenodes)
        ns = SyntheticNamespace(NamespaceSpec(), n_dirs=n_dirs,
                                files_per_dir=4)
        materialize_namespace(cluster.namenodes[0], ns)
        return store, cluster

    window = batch_size * 8
    modes: Dict[str, Dict] = {}
    agg = {"hintchain_launches": 0, "pkval_launches": 0, "pkval_probes": 0,
           "pkval_demotions": 0, "treeagg_launches": 0,
           "treeagg_demotions": 0}
    total_ops = 0
    wall_dict = wall_col = 0.0
    state_all = True
    for mode, mix_kw in (("spotify", {}),
                         ("write_heavy", {"mix": WRITE_HEAVY_MIX})):
        ns = SyntheticNamespace(NamespaceSpec(), n_dirs=n_dirs,
                                files_per_dir=4)
        trace = make_spotify_trace(ns, n_ops, seed=5, **mix_kw)
        runs: Dict[str, Dict] = {}
        for backend, cls in (("dict", MetadataStore),
                             ("columnar", ColumnarMetadataStore)):
            store, cluster = build(cls)
            pipe = PlannedRequestPipeline(cluster, batch_size=batch_size,
                                          window=window)
            t0 = time.time()
            stats = pipe.run(list(trace))
            wall = time.time() - t0
            rep = pipe.plan_report
            runs[backend] = {
                "store": store,
                "wall": wall,
                "windows": rep.windows,
                "ok": stats.ok,
                "failed": stats.failed,
                "hintchain_launches": rep.hintchain_launches,
                "pkval_launches": rep.pkval_launches
                + sum(nn.pkval_launches for nn in cluster.namenodes),
                "pkval_probes": rep.pkval_probes
                + sum(nn.pkval_probes for nn in cluster.namenodes),
                "pkval_demotions": rep.pkval_demotions
                + sum(nn.pkval_demotions for nn in cluster.namenodes),
                "treeagg_launches": sum(nn.treeagg_launches
                                        for nn in cluster.namenodes),
                "treeagg_demotions": sum(nn.treeagg_demotions
                                         for nn in cluster.namenodes),
            }
        d, c = runs["dict"], runs["columnar"]
        # the oracle lock: bit-identical rows, PKs and costs aside from
        # nothing — the columnar engine is a LAYOUT, not a behaviour
        state_equal = (d["store"].dump_state() == c["store"].dump_state())
        state_all = state_all and state_equal
        windows = max(1, c["windows"])
        modes[mode] = {
            "ops": len(trace),
            "ok": c["ok"],
            "failed": c["failed"],
            "windows": c["windows"],
            "hintchain_launches": c["hintchain_launches"],
            "pkval_launches": c["pkval_launches"],
            "pkval_probes": c["pkval_probes"],
            "pkval_demotions": c["pkval_demotions"],
            "treeagg_launches": c["treeagg_launches"],
            "treeagg_demotions": c["treeagg_demotions"],
            "window_ms_dict": round(1e3 * d["wall"]
                                    / max(1, d["windows"]), 2),
            "window_ms_columnar": round(1e3 * c["wall"] / windows, 2),
            "state_matches_oracle": state_equal,
        }
        for k in agg:
            agg[k] += modes[mode][k]
        total_ops += len(trace)
        wall_dict += d["wall"]
        wall_col += c["wall"]
    fused = agg["hintchain_launches"] + agg["pkval_launches"]
    return {
        "batch_size": batch_size,
        "window": window,
        "n_namenodes": n_namenodes,
        "ops": total_ops,
        "modes": modes,
        "hintchain_launches": agg["hintchain_launches"],
        "pkval_launches": agg["pkval_launches"],
        "pkval_probes": agg["pkval_probes"],
        "pkval_demotions": agg["pkval_demotions"],
        "treeagg_launches": agg["treeagg_launches"],
        "treeagg_demotions": agg["treeagg_demotions"],
        "fused_launches": fused,
        "launches_per_op": round(fused / max(1, total_ops), 4),
        "wall_s_dict": round(wall_dict, 2),
        "wall_s_columnar": round(wall_col, 2),
        "state_matches_oracle": state_all,
    }


def big_dir_report(*, n_children: int = 100_000, n_ops: int = 400,
                   batch_size: int = 1000, seed: int = 23) -> Dict:
    """Million-entry-directory bench (paper §6: subtree ops as "many small
    parallel transactions" that do NOT stall the cluster).

    One flat directory of ``n_children`` files is deleted through the
    incremental subtree protocol while a BIG_DIR_MIX side trace keeps
    running — the delete's pace hook replays one adjacent op between
    every chunk commit, so the reported paced p50/p99 are latencies
    *measured while the subtree op holds its lock*, compared against the
    identical mix with no subtree op running.  Run on both backends: the
    dict oracle and the columnar store (whose du aggregation + phase-2
    wave advisory launch the fused treeagg kernel), with ``dump_state``
    byte-equality across backends AND incremental-vs-legacy as the locks.
    """
    from repro.core import materialize_big_dir
    from repro.core.columnar import ColumnarMetadataStore
    from repro.core.ops_registry import WorkloadOp
    from repro.core.workload import BIG_DIR_MIX, make_big_dir_namespace

    def build(store_cls, n_kids):
        store = store_cls(n_datanodes=4)
        format_fs(store)
        cluster = NamenodeCluster(store, 1)
        nn = cluster.namenodes[0]
        ns, big, _ = make_big_dir_namespace(n_kids)
        materialize_namespace(nn, ns)
        materialize_big_dir(nn, big, n_kids)
        nn.subtree.batch_size = batch_size
        return store, nn, ns, big

    def pct(lat, q):
        if not lat:
            return 0.0
        s = sorted(lat)
        return round(s[min(len(s) - 1, int(q * len(s)))] * 1e3, 3)

    def run_op(nn, wop, lat):
        t0 = time.perf_counter()
        try:
            nn.invoke(wop)
            ok = True
        except Exception:
            ok = False
        lat.append(time.perf_counter() - t0)
        return ok

    runs: Dict[str, Dict] = {}
    for backend, cls in (("dict", MetadataStore),
                         ("columnar", ColumnarMetadataStore)):
        store, nn, ns, big = build(cls, n_children)
        # identical traces on both backends: same ns plan, same seeds
        base_trace = make_spotify_trace(ns, n_ops, seed=seed,
                                        mix=BIG_DIR_MIX)
        paced_trace = make_spotify_trace(ns, n_ops, seed=seed + 1,
                                         mix=BIG_DIR_MIX)
        base_lat: List[float] = []
        for wop in base_trace:
            run_op(nn, wop, base_lat)
        paced_lat: List[float] = []
        it = iter(paced_trace)
        paces = [0]
        busy = [False]       # re-entrancy guard: a paced op must never
                             # drive the pace hook again

        def pace():
            if busy[0]:
                return
            wop = next(it, None)
            if wop is None:
                return
            busy[0] = True
            try:
                paces[0] += 1
                run_op(nn, wop, paced_lat)
            finally:
                busy[0] = False

        nn.subtree.pace = pace
        t0 = time.time()
        res = nn.invoke(WorkloadOp("delete_subtree", big, on_dir=True))
        wall = time.time() - t0
        nn.subtree.pace = None
        for wop in it:       # drain: both backends run the full trace
            run_op(nn, wop, paced_lat)
        runs[backend] = {
            "store": store,
            "wall": wall,
            "deleted": res.value["deleted"],
            "stats": dict(nn.subtree.last_stats),
            "paces": paces[0],
            "base_p50": pct(base_lat, 0.50), "base_p99": pct(base_lat, 0.99),
            "paced_p50": pct(paced_lat, 0.50),
            "paced_p99": pct(paced_lat, 0.99),
            "treeagg_launches": nn.treeagg_launches,
            "treeagg_demotions": nn.treeagg_demotions,
        }
    d, c = runs["dict"], runs["columnar"]
    state_equal = d["store"].dump_state() == c["store"].dump_state()

    # incremental vs legacy differential: same (smaller) build + trace on
    # two dict stores, only the phase-2/3 machinery differs
    n_small = max(1000, n_children // 10)
    dumps = []
    for incremental in (True, False):
        store, nn, ns, big = build(MetadataStore, n_small)
        nn.subtree.incremental = incremental
        for wop in make_spotify_trace(ns, min(n_ops, 100), seed=seed + 2,
                                      mix=BIG_DIR_MIX):
            try:
                nn.invoke(wop)
            except Exception:
                pass
        nn.invoke(WorkloadOp("delete_subtree", big, on_dir=True))
        dumps.append(store.dump_state())
    inc_equal = dumps[0] == dumps[1]

    st = c["stats"]
    return {
        "n_children": n_children,
        "total_inodes": n_children + 1,
        "batch_size": batch_size,
        "deleted": c["deleted"],
        "chunks": st["chunks"],
        "waves": st["waves"],
        "peak_frontier": st["peak_frontier"],
        "subtree_wall_s_dict": round(d["wall"], 2),
        "subtree_wall_s_columnar": round(c["wall"], 2),
        "adjacent_ops": n_ops,
        "pace_invocations": c["paces"],
        "baseline_p50_ms": c["base_p50"],
        "baseline_p99_ms": c["base_p99"],
        "paced_p50_ms": c["paced_p50"],
        "paced_p99_ms": c["paced_p99"],
        "p99_ratio": round(c["paced_p99"] / max(c["base_p99"], 1e-9), 2),
        "treeagg_launches": c["treeagg_launches"],
        "treeagg_demotions": c["treeagg_demotions"],
        "state_matches_oracle": state_equal,
        "incremental_matches_legacy": inc_equal,
    }


def run_replay(*, quick: bool = False, namenode_counts=(1, 4, 16),
               batch_size: int = 16, trace_ops: int = 5000,
               seed: int = 11) -> Dict:
    horizon = 0.15 if quick else 0.3
    ns = SyntheticNamespace(NamespaceSpec(), n_dirs=60)
    trace = make_spotify_trace(ns, trace_ops if not quick else 2000,
                               seed=seed)
    profiles = profile_ops()
    points = []
    for n in namenode_counts:
        pt = replay_des(trace, profiles, n_namenodes=n,
                        batch_size=batch_size, horizon=horizon)
        planned_pt = replay_des(trace, profiles, n_namenodes=n,
                                batch_size=batch_size, horizon=horizon,
                                planned=True)
        pt["planned_throughput_ops_s"] = planned_pt["throughput_ops_s"]
        pt["planned_batched_ops"] = planned_pt["batched_ops"]
        points.append(pt)
    # speedup vs the smallest namenode count actually measured (only
    # "vs 1 NN" when the sweep includes 1, e.g. the default 1,4,16)
    base_pt = min(points, key=lambda p: p["namenodes"])
    base = base_pt["throughput_ops_s"] or 1.0
    for pt in points:
        pt["speedup_vs_min_nn"] = round(pt["throughput_ops_s"] / base, 2)
        pt["baseline_namenodes"] = base_pt["namenodes"]
    func = functional_batching_report(
        make_spotify_trace(SyntheticNamespace(NamespaceSpec(), n_dirs=20,
                                              files_per_dir=4),
                           300 if quick else 600, seed=5),
        batch_size=batch_size)
    # the lease-ordered grouped block-write path under an ingest-shaped
    # mix: create/add_block/complete/append dominate, so
    # batched_write_fraction is the headline here
    func_w = functional_batching_report(
        make_spotify_trace(SyntheticNamespace(NamespaceSpec(), n_dirs=20,
                                              files_per_dir=4),
                           300 if quick else 600, seed=5,
                           mix=WRITE_HEAVY_MIX),
        batch_size=batch_size)
    failover = failover_report(trace, profiles, batch_size=batch_size,
                               horizon=horizon)
    elasticity = elasticity_report(trace, profiles, batch_size=batch_size,
                                   horizon=horizon,
                                   phase_ops=300 if quick else 600)
    overload = overload_report(batch_size=batch_size,
                               n_ops=300 if quick else 600)
    columnar = columnar_report(batch_size=batch_size,
                               n_ops=300 if quick else 600)
    big_dir = big_dir_report(n_children=4000 if quick else 100_000,
                             n_ops=150 if quick else 400)
    return {
        "benchmark": "trace_replay_throughput",
        "paper_figure": "Fig 7 (throughput vs number of namenodes)",
        "trace": {
            "mix": [{"op": op, "weight_pct": w, "dir_fraction": d}
                    for op, w, d in SPOTIFY_TRACE_MIX],
            "n_ops": len(trace),
            "seed": seed,
        },
        "write_heavy_mix": [{"op": op, "weight_pct": w, "dir_fraction": d}
                            for op, w, d in WRITE_HEAVY_MIX],
        "params": {
            "batch_size": batch_size,
            "n_ndb": 8,
            "horizon_s": horizon,
            "quick": quick,
        },
        "scaling": points,
        "functional_batching": func,
        "functional_batching_write_heavy": func_w,
        "failover": failover,
        "elasticity": elasticity,
        "overload": overload,
        "columnar": columnar,
        "big_dir": big_dir,
    }


def bench_trace_replay(quick: bool = False) -> List[Row]:
    """Row-formatted entry point for benchmarks/run.py."""
    report = run_replay(quick=quick,
                        namenode_counts=(1, 4) if quick else (1, 4, 16))
    rows: List[Row] = []
    for pt in report["scaling"]:
        rows.append((f"trace_replay.hops_{pt['namenodes']}nn", 0.0,
                     f"{pt['throughput_ops_s']:,.0f} ops/s "
                     f"({pt['speedup_vs_min_nn']}x vs "
                     f"{pt['baseline_namenodes']} NN)"))
    f = report["functional_batching"]
    rows.append(("trace_replay.functional_savings", 0.0,
                 f"{f['round_trip_savings_pct']}% fewer DB round trips "
                 f"at batch={f['batch_size']} "
                 f"(state match: {f['state_matches_sequential']})"))
    bd = report["big_dir"]
    rows.append(("trace_replay.big_dir", 0.0,
                 f"paced delete of {bd['total_inodes']:,} inodes: "
                 f"adjacent p99 x{bd['p99_ratio']}, "
                 f"{bd['treeagg_launches']} treeagg launches "
                 f"(oracle match: {bd['state_matches_oracle']})"))
    rows.append(("trace_replay.planner_savings", 0.0,
                 f"planned {f['planned_vs_reactive_savings_pct']}% fewer "
                 f"RTs vs reactive; batched "
                 f"{f['planned_batched_fraction']} "
                 f"(writes {f['batched_write_fraction']}), local RT "
                 f"{f['local_rt_fraction']['planned']}"))
    w = report["functional_batching_write_heavy"]
    rows.append(("trace_replay.write_heavy_block_path", 0.0,
                 f"write-heavy: batched writes "
                 f"{w['batched_write_fraction']}, planned "
                 f"{w['planned_vs_reactive_savings_pct']}% fewer RTs vs "
                 f"reactive (state match: "
                 f"{w['state_matches_sequential']})"))
    wc = w["planned_concurrent"]
    rows.append(("trace_replay.planned_concurrent", 0.0,
                 f"concurrent planned: batched writes "
                 f"{wc['batched_write_fraction']}, "
                 f"{wc['vs_reactive_savings_pct']}% fewer RTs vs reactive, "
                 f"hint hit rate {wc['hint_cache']['hit_rate']}"))
    fo = report["failover"]
    rows.append(("trace_replay.failover", 0.0,
                 f"kill 1/{fo['n_namenodes']} NN mid-replay: dip "
                 f"{fo['dip_depth_pct']}%, recovery {fo['recovery_s']} s "
                 f"({fo['ops_to_recovery']} ops), "
                 f"{fo['zero_bins_after_kill']} zero bins (paper: none)"))
    ov = report["overload"]
    rows.append(("trace_replay.overload", 0.0,
                 f"gray-slow NN: goodput "
                 f"{ov['unprotected']['goodput_frac']} -> "
                 f"{ov['protected']['goodput_frac']} protected, late "
                 f"{ov['unprotected']['late_completions']} -> "
                 f"{ov['protected']['late_completions']}, "
                 f"{ov['breaker_trips']} breaker trips (state match: "
                 f"{ov['state_matches_sequential']})"))
    co = report["columnar"]
    rows.append(("trace_replay.columnar", 0.0,
                 f"columnar engine: {co['fused_launches']} fused launches "
                 f"for {co['ops']} ops ({co['launches_per_op']}/op), "
                 f"{co['pkval_probes']} PKs validated, state match: "
                 f"{co['state_matches_oracle']}"))
    el = report["elasticity"]
    rows.append(("trace_replay.elasticity", 0.0,
                 f"scale-out {el['n_namenodes_base']}->"
                 f"{el['n_namenodes_peak']} NN: +{el['scale_out_gain_pct']}%"
                 f" throughput, {el['zero_bins_during_scale_out']} zero "
                 f"bins; hint hit rate {el['hint_hit_rate_before']} -> "
                 f"{el['hint_hit_rate_after']} after warm migration "
                 f"(state match: {el['state_matches_sequential']})"))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    ap.add_argument("--namenodes", default="1,4,16",
                    help="comma-separated namenode counts")
    ap.add_argument("--batch-size", type=int, default=16)
    ap.add_argument("--only", choices=("big_dir",),
                    help="run a single report section (CI uses this to "
                         "regenerate one section without touching the "
                         "committed artifact)")
    args = ap.parse_args()

    if args.only == "big_dir":
        t0 = time.time()
        bd = big_dir_report(n_children=4000 if args.quick else 100_000,
                            n_ops=150 if args.quick else 400)
        bd["wall_s"] = round(time.time() - t0, 1)
        args.out.write_text(json.dumps({"big_dir": bd}, indent=2) + "\n")
        _print_big_dir(bd)
        print(f"wrote {args.out}")
        return

    counts = tuple(int(x) for x in args.namenodes.split(","))
    t0 = time.time()
    report = run_replay(quick=args.quick, namenode_counts=counts,
                        batch_size=args.batch_size)
    report["wall_s"] = round(time.time() - t0, 1)
    args.out.write_text(json.dumps(report, indent=2) + "\n")
    for pt in report["scaling"]:
        print(f"namenodes={pt['namenodes']:3d}  "
              f"throughput={pt['throughput_ops_s']:12,.1f} ops/s  "
              f"p99={pt['latency_p99_ms']:.1f} ms  "
              f"speedup={pt['speedup_vs_min_nn']}x")
    f = report["functional_batching"]
    print(f"functional: {f['round_trip_savings_pct']}% round-trip savings "
          f"(reactive), {f['planned_savings_pct']}% (planned; "
          f"{f['planned_vs_reactive_savings_pct']}% vs reactive), "
          f"state_matches_sequential={f['state_matches_sequential']}")
    lf = f["local_rt_fraction"]
    print(f"local RT share: seq {lf['sequential']} -> reactive "
          f"{lf['reactive']} -> planned {lf['planned']}; batched writes "
          f"{f['batched_write_fraction']}")
    w = report["functional_batching_write_heavy"]
    print(f"write-heavy mix: batched writes {w['batched_write_fraction']} "
          f"(lease-ordered {w['planner']['lease_ordered_ops']} ops), "
          f"planned {w['planned_vs_reactive_savings_pct']}% fewer RTs vs "
          f"reactive, state_matches_sequential="
          f"{w['state_matches_sequential']}")
    wc = w["planned_concurrent"]
    print(f"planned+concurrent (write-heavy): batched writes "
          f"{wc['batched_write_fraction']} "
          f"(deterministic {w['batched_write_fraction']}), "
          f"{wc['vs_reactive_savings_pct']}% fewer RTs vs reactive, "
          f"client hint hit rate {wc['hint_cache']['hit_rate']} "
          f"(stale {wc['hint_cache']['stale_overwrites']})")
    hc = f["hint_cache"]
    print(f"closed loop (spotify): client hint hit rate {hc['hit_rate']}, "
          f"windows {f['planner']['window_sizes']}")
    fo = report["failover"]
    print(f"failover: killed NN {fo['killed_namenode']}/"
          f"{fo['n_namenodes']} at {fo['kill_at_s']} s -> dip "
          f"{fo['dip_depth_pct']}% of steady, recovered in "
          f"{fo['recovery_s']} s ({fo['ops_to_recovery']} ops), "
          f"{fo['zero_bins_after_kill']} zero bins after kill")
    el = report["elasticity"]
    print(f"elasticity: {el['n_namenodes_base']}->"
          f"{el['n_namenodes_peak']}->{el['n_namenodes_base']} NN, "
          f"+{el['scale_out_gain_pct']}% during scale-out "
          f"({el['zero_bins_during_scale_out']} zero bins), scale-in "
          f"settled in {el['scale_in_recovery_s']} s; pool "
          f"{el['pool_scale_outs']} out/{el['pool_scale_ins']} in, "
          f"hint hit rate {el['hint_hit_rate_before']} -> "
          f"{el['hint_hit_rate_after']} "
          f"({el['migrated_hint_entries']} entries migrated), "
          f"state_matches_sequential={el['state_matches_sequential']}")
    ov = report["overload"]
    print(f"overload: 1 gray-slow NN of {ov['n_namenodes']}, goodput "
          f"{ov['unprotected']['goodput_frac']} -> "
          f"{ov['protected']['goodput_frac']} protected "
          f"(+{ov['goodput_gain_pct']}%), late completions "
          f"{ov['unprotected']['late_completions']} -> "
          f"{ov['protected']['late_completions']}, worst tenant p99 "
          f"{ov['unprotected']['worst_tenant_p99_ticks']} -> "
          f"{ov['protected']['worst_tenant_p99_ticks']} ticks, "
          f"{ov['breaker_trips']} breaker trips, "
          f"state_matches_sequential={ov['state_matches_sequential']}")
    co = report["columnar"]
    print(f"columnar: {co['hintchain_launches']} hintchain + "
          f"{co['pkval_launches']} pkval launches over {co['ops']} ops "
          f"({co['launches_per_op']} launches/op), "
          f"{co['pkval_probes']} PK probes ({co['pkval_demotions']} "
          f"demoted), wall {co['wall_s_dict']} s dict -> "
          f"{co['wall_s_columnar']} s columnar, "
          f"state_matches_oracle={co['state_matches_oracle']}")
    _print_big_dir(report["big_dir"])
    print(f"wrote {args.out}")


def _print_big_dir(bd: Dict) -> None:
    print(f"big_dir: paced delete of {bd['total_inodes']:,} inodes in "
          f"{bd['chunks']} chunks ({bd['waves']} waves, peak frontier "
          f"{bd['peak_frontier']:,}), wall {bd['subtree_wall_s_dict']} s "
          f"dict / {bd['subtree_wall_s_columnar']} s columnar; adjacent "
          f"p99 {bd['baseline_p99_ms']} -> {bd['paced_p99_ms']} ms "
          f"({bd['p99_ratio']}x), {bd['treeagg_launches']} treeagg "
          f"launches ({bd['treeagg_demotions']} demoted), "
          f"state_matches_oracle={bd['state_matches_oracle']}, "
          f"incremental_matches_legacy={bd['incremental_matches_legacy']}")


if __name__ == "__main__":
    main()
